//! Shared gate-level building blocks (paper §3.2–3.3).

use crate::arith::table::CorrectionTables;
use crate::fabric::netlist::{Net, Netlist, NET0, NET1};

/// 4:1 mux in a single LUT6 (4 data + 2 select inputs).
pub fn mux4(nl: &mut Netlist, sel: [Net; 2], d: [Net; 4]) -> Net {
    nl.lut(&[d[0], d[1], d[2], d[3], sel[0], sel[1]], |m| {
        let s = (m >> 4) & 3;
        (m >> s) & 1 == 1
    })
}

/// Leading-one detector over `bits` (multiple of 4) using the paper's
/// 4-bit segmentation: per segment one zero-flag LUT plus one LUT6_2
/// (fractured into two 5-LUTs) revealing the in-segment position — two
/// 6-LUTs per segment, detected in parallel (§3.2).
///
/// Returns `(k, nonzero)` where `k` is the ⌈log2(bits)⌉-bit position of the
/// leading one (undefined when `nonzero = 0`).
pub fn lod(nl: &mut Netlist, a: &[Net]) -> (Vec<Net>, Net) {
    let bits = a.len() as u32;
    assert!(bits % 4 == 0, "LOD needs a multiple of 4 bits");
    let segs = (bits / 4) as usize;

    // Per-segment: zero flag + 2-bit in-segment position.
    let mut zero = Vec::with_capacity(segs);
    let mut pos0 = Vec::with_capacity(segs);
    let mut pos1 = Vec::with_capacity(segs);
    for s in 0..segs {
        let seg = &a[4 * s..4 * s + 4];
        let z = nl.lut(seg, |m| m == 0);
        // pos within segment: 3 if b3 else 2 if b2 else 1 if b1 else 0.
        let (p0, p1) = nl.lut52(
            seg,
            |m| (m >> 3) & 1 == 1 || ((m >> 2) & 1 == 0 && (m >> 1) & 1 == 1),
            |m| (m >> 3) & 1 == 1 || (m >> 2) & 1 == 1,
        );
        zero.push(z);
        pos0.push(p0);
        pos1.push(p1);
    }

    // Priority select: the most-significant non-zero segment wins.
    // sel_s = !z_s & z_{s+1} & … & z_{segs-1}   (one LUT each, ≤ 6 wide;
    // for 8 segments the tail AND is folded via an extra level).
    let mut sel = vec![NET0; segs];
    for s in 0..segs {
        let above: Vec<Net> = zero[s + 1..].to_vec();
        if above.len() <= 5 {
            let mut ins = vec![zero[s]];
            ins.extend(&above);
            let n_above = above.len() as u32;
            sel[s] = nl.lut(&ins, move |m| m & 1 == 0 && (m >> 1) == (1 << n_above) - 1);
        } else {
            // Fold the tail: all-zero-above flag first.
            let n_tail = (above.len() - 4) as u32;
            let tail = nl.lut(&above[4..], move |m| m == (1 << n_tail) - 1);
            let ins = [zero[s], above[0], above[1], above[2], above[3], tail];
            sel[s] = nl.lut(&ins, |m| m & 1 == 0 && (m >> 1) == 0b11111);
        }
    }

    // k = seg_index*4 + pos[selected]: OR-combine masked contributions.
    let kbits = (31 - bits.leading_zeros()) as usize; // log2(bits), e.g. 4 for 16
    let mut k = Vec::with_capacity(kbits);
    // k bit 0/1 from in-segment position; bits ≥ 2 from the segment index.
    for bit in 0..kbits {
        let mut terms = Vec::new();
        for s in 0..segs {
            let contrib = match bit {
                0 => Some(pos0[s]),
                1 => Some(pos1[s]),
                _ => {
                    if (s >> (bit - 2)) & 1 == 1 {
                        Some(NET1)
                    } else {
                        None
                    }
                }
            };
            if let Some(c) = contrib {
                if c == NET1 {
                    terms.push(sel[s]);
                } else {
                    terms.push(nl.and2(sel[s], c));
                }
            }
        }
        k.push(nl.or_tree(&terms));
    }
    let nz: Vec<Net> = zero.clone();
    let all_zero = nl.lut(&nz[..nz.len().min(6)], |m| m == (1 << nz.len().min(6)) - 1);
    let nonzero = if segs <= 6 {
        nl.not(all_zero)
    } else {
        let rest = nl.lut(&nz[6..], |m| m != (1 << (nz.len() - 6)) - 1);
        let head = nl.not(all_zero);
        nl.or2(head, rest)
    };
    (k, nonzero)
}

/// Left barrel shifter: `out[i] = in[i - shift]` over `out_width` bits,
/// `shift` given as a little-endian bit bus. Amount bits are consumed in
/// pairs so each level is a 4:1 mux (one LUT6 per output bit per pair).
pub fn barrel_left(nl: &mut Netlist, input: &[Net], shift: &[Net], out_width: usize) -> Vec<Net> {
    let mut cur: Vec<Net> = input.to_vec();
    cur.resize(out_width.max(input.len()), NET0);
    let mut j = 0;
    while j < shift.len() {
        if j + 1 < shift.len() {
            let step = 1usize << j;
            let next: Vec<Net> = (0..cur.len())
                .map(|i| {
                    let d0 = cur[i];
                    let d1 = if i >= step { cur[i - step] } else { NET0 };
                    let d2 = if i >= 2 * step { cur[i - 2 * step] } else { NET0 };
                    let d3 = if i >= 3 * step { cur[i - 3 * step] } else { NET0 };
                    if d0 == d1 && d1 == d2 && d2 == d3 {
                        d0
                    } else {
                        mux4(nl, [shift[j], shift[j + 1]], [d0, d1, d2, d3])
                    }
                })
                .collect();
            cur = next;
            j += 2;
        } else {
            let step = 1usize << j;
            let next: Vec<Net> = (0..cur.len())
                .map(|i| {
                    let lo = cur[i];
                    let hi = if i >= step { cur[i - step] } else { NET0 };
                    if lo == hi { lo } else { nl.mux2(shift[j], lo, hi) }
                })
                .collect();
            cur = next;
            j += 1;
        }
    }
    cur.truncate(out_width);
    cur
}

/// Right barrel shifter: `out[i] = in[i + shift]`; shifts past the input
/// width produce 0.
pub fn barrel_right(nl: &mut Netlist, input: &[Net], shift: &[Net], out_width: usize) -> Vec<Net> {
    let mut cur: Vec<Net> = input.to_vec();
    let mut j = 0;
    while j < shift.len() {
        let take = |cur: &Vec<Net>, i: usize| cur.get(i).copied().unwrap_or(NET0);
        if j + 1 < shift.len() {
            let step = 1usize << j;
            let next: Vec<Net> = (0..cur.len())
                .map(|i| {
                    let d = [
                        take(&cur, i),
                        take(&cur, i + step),
                        take(&cur, i + 2 * step),
                        take(&cur, i + 3 * step),
                    ];
                    if d[0] == d[1] && d[1] == d[2] && d[2] == d[3] {
                        d[0]
                    } else {
                        mux4(nl, [shift[j], shift[j + 1]], d)
                    }
                })
                .collect();
            cur = next;
            j += 2;
        } else {
            let step = 1usize << j;
            let next: Vec<Net> = (0..cur.len())
                .map(|i| {
                    let lo = take(&cur, i);
                    let hi = take(&cur, i + step);
                    if lo == hi { lo } else { nl.mux2(shift[j], lo, hi) }
                })
                .collect();
            cur = next;
            j += 1;
        }
    }
    cur.truncate(out_width);
    cur
}

/// Fraction aligner (§3.2): given operand `a` and its leading-one position
/// `k`, produce the `F = bits−1`-bit fraction `(a − 2^k) << (F − k)`.
///
/// `F − k` = bitwise-NOT of `k` for `k` in `0..bits` when `bits` is a power
/// of two, so the shift amount is free (folded into the mux LUTs).
pub fn align_fraction(nl: &mut Netlist, a: &[Net], k: &[Net]) -> Vec<Net> {
    let bits = a.len();
    let f = bits - 1;
    // shift = F - k = !k (bitwise), since F = 2^log2(bits) - 1.
    let nshift: Vec<Net> = k.iter().map(|&kb| nl.not(kb)).collect();
    // Shift the low F bits of a (the leading one at bit k lands on bit F
    // and is dropped).
    let shifted = barrel_left(nl, &a[..f], &nshift, f);
    shifted
}

/// The paper's §3.3 error-LUT bank: `w` LUT6s, each fed the 3 MSBs of both
/// fractions, producing coefficient bit `2^-(3+i)` (i = 0..w−1). Returns
/// the coefficient magnitude bus in F-bit fraction units, MSB-first list
/// converted to an LSB-first bus of width F (sign handled by the caller —
/// multiplier coefficients are positive, divider ones negative).
pub fn error_lut_bank(
    nl: &mut Netlist,
    table: &CorrectionTables,
    is_div: bool,
    frac1: &[Net],
    frac2: &[Net],
) -> Vec<Net> {
    let f = frac1.len();
    assert_eq!(frac2.len(), f);
    let w = table.w;
    let ins = [
        frac1[f - 3], frac1[f - 2], frac1[f - 1],
        frac2[f - 3], frac2[f - 2], frac2[f - 1],
    ];
    // Coefficient magnitude at resolution 2^-12, per region. Input m:
    // bits 0..2 = frac1[F−3..F−1] (region index i LSB-first), bits 3..5
    // likewise for frac2.
    let tbl = if is_div { table.div } else { table.mul };
    let entry = move |m: u32| {
        let i = (m & 7) as usize;
        let j = ((m >> 3) & 7) as usize;
        tbl[i][j].unsigned_abs()
    };
    // Bit 2^-(3+i) of |c| is bit (12-3-i) of the fixed-point value.
    let mut coeff_bits = Vec::with_capacity(w as usize);
    for i in 0..w {
        let bitpos = 12 - 3 - i; // 9 down to 2 for w = 8
        coeff_bits.push(nl.lut(&ins, move |m| (entry(m) >> bitpos) & 1 == 1));
    }
    // Assemble the F-bit bus: coefficient bit i sits at F-3-i… positions
    // below 0 are dropped (sub-ulp at small widths).
    let mut bus = vec![NET0; f];
    for (i, &cb) in coeff_bits.iter().enumerate() {
        let pos = f as i32 - 3 - i as i32;
        if pos >= 0 {
            bus[pos as usize] = cb;
        }
    }
    bus
}

/// Negated divider-coefficient bank: emits the two's complement
/// `(-|c|) mod 2^(F+2)` of the region's correction directly — each output
/// bit is still one region-indexed LUT (the negation is constant per
/// region, so it folds into the LUT INIT). Feeding this bus into the
/// single [`crate::fabric::Netlist::ternary_subtract`] pass applies the
/// negative correction with **no** extra carry chain (paper §3.3's
/// "delay nearly untouched" argument).
pub fn error_lut_bank_neg(
    nl: &mut Netlist,
    table: &CorrectionTables,
    frac1: &[Net],
    frac2: &[Net],
) -> Vec<Net> {
    let f = frac1.len();
    assert_eq!(frac2.len(), f);
    let bits = f as u32 + 1;
    let width = f + 2;
    let ins = [
        frac1[f - 3], frac1[f - 2], frac1[f - 1],
        frac2[f - 3], frac2[f - 2], frac2[f - 1],
    ];
    // Per-region constant: (-scale_to_f(c)) mod 2^(F+2). Note div table
    // entries are ≤ 0, so the negation is a non-negative magnitude…
    // scale_to_f returns the signed value; -that is ≥ 0, then the mod
    // wraps nothing. To apply the *negative* correction we need
    // (+scale_to_f) two's complement: scale_to_f ≤ 0 already, so the
    // addend is scale_to_f mod 2^(F+2).
    let konst = move |m: u32| -> u64 {
        let i = (m & 7) as usize;
        let j = ((m >> 3) & 7) as usize;
        let c = CorrectionTables::scale_to_f(table.div[i][j], bits);
        (c as i128).rem_euclid(1i128 << width) as u64
    };
    (0..width)
        .map(|p| {
            // Constant-fold bit positions where all regions agree.
            let mut any0 = false;
            let mut any1 = false;
            for m in 0..64u32 {
                if (konst(m) >> p) & 1 == 1 {
                    any1 = true;
                } else {
                    any0 = true;
                }
            }
            match (any0, any1) {
                (true, false) => NET0,
                (false, true) => NET1,
                _ => nl.lut(&ins, move |m| (konst(m) >> p) & 1 == 1),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Simulator;

    #[test]
    fn mux4_selects() {
        let mut nl = Netlist::new();
        let d = nl.input("d", 4);
        let s = nl.input("s", 2);
        let m = mux4(&mut nl, [s[0], s[1]], [d[0], d[1], d[2], d[3]]);
        nl.output("m", &[m]);
        let sim = Simulator::new(&nl);
        for sel in 0..4u64 {
            for dv in 0..16u64 {
                let got = sim.run_single(&[("d", dv), ("s", sel)])[0].1;
                assert_eq!(got, (dv >> sel) & 1, "d={dv:04b} s={sel}");
            }
        }
    }

    #[test]
    fn lod_16bit_exhaustive() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 16);
        let (k, nz) = lod(&mut nl, &a);
        let mut out = k;
        out.push(nz);
        nl.output("k", &out);
        let sim = Simulator::new(&nl);
        let vals: Vec<u64> = (0..65536u64).collect();
        let outs = sim.run_batch(&[("a", &vals)]);
        for (i, &v) in vals.iter().enumerate() {
            let got = outs[0].1[i];
            if v == 0 {
                assert_eq!(got >> 4, 0, "nonzero flag for 0");
            } else {
                let want_k = 63 - v.leading_zeros() as u64;
                assert_eq!(got & 0xF, want_k, "v={v:#x}");
                assert_eq!(got >> 4, 1, "v={v:#x} nz");
            }
        }
    }

    #[test]
    fn lod_32bit_sampled() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 32);
        let (k, nz) = lod(&mut nl, &a);
        let mut out = k;
        out.push(nz);
        nl.output("k", &out);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..20_000 {
            let v = rng.operand(32);
            let got = sim.run_single(&[("a", v)])[0].1;
            assert_eq!(got & 0x1F, 63 - v.leading_zeros() as u64, "v={v:#x}");
            assert_eq!(got >> 5, 1);
        }
    }

    #[test]
    fn lod_area_is_two_luts_per_segment_plus_combine() {
        // Paper: two 6-LUTs per 4-bit segment for detection; the priority
        // combine adds a small constant overhead.
        let mut nl = Netlist::new();
        let a = nl.input("a", 16);
        let _ = lod(&mut nl, &a);
        let r = crate::fabric::area::report(&nl);
        assert!(r.luts >= 8, "4 segments × 2 LUTs minimum, got {}", r.luts);
        assert!(r.luts <= 26, "combine overhead too large: {}", r.luts);
    }

    #[test]
    fn barrel_left_matches_shift() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 8);
        let s = nl.input("s", 3);
        let out = barrel_left(&mut nl, &a, &s, 16);
        nl.output("o", &out);
        let sim = Simulator::new(&nl);
        for v in [0u64, 1, 0x5A, 0xFF] {
            for sh in 0..8u64 {
                let got = sim.run_single(&[("a", v), ("s", sh)])[0].1;
                assert_eq!(got, (v << sh) & 0xFFFF, "v={v:#x} sh={sh}");
            }
        }
    }

    #[test]
    fn barrel_right_matches_shift() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 16);
        let s = nl.input("s", 5);
        let out = barrel_right(&mut nl, &a, &s, 16);
        nl.output("o", &out);
        let sim = Simulator::new(&nl);
        for v in [1u64, 0xABCD, 0xFFFF] {
            for sh in 0..32u64 {
                let got = sim.run_single(&[("a", v), ("s", sh)])[0].1;
                assert_eq!(got, if sh >= 64 { 0 } else { v >> sh }, "v={v:#x} sh={sh}");
            }
        }
    }

    #[test]
    fn align_fraction_matches_behavioral() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 16);
        let (k, _nz) = lod(&mut nl, &a);
        let frac = align_fraction(&mut nl, &a, &k);
        nl.output("f", &frac);
        let sim = Simulator::new(&nl);
        let vals: Vec<u64> = (1..65536u64).step_by(17).collect();
        let outs = sim.run_batch(&[("a", &vals)]);
        for (i, &v) in vals.iter().enumerate() {
            let (_, want) =
                crate::arith::frac_aligned(16, std::num::NonZeroU64::new(v).expect("v >= 1"));
            assert_eq!(outs[0].1[i], want, "v={v}");
        }
    }

    #[test]
    fn error_lut_bank_area_is_w_luts() {
        use crate::arith::table::tables_for;
        for w in [1u32, 4, 8] {
            let mut nl = Netlist::new();
            let f1 = nl.input("f1", 15);
            let f2 = nl.input("f2", 15);
            let before = crate::fabric::area::report(&nl).luts;
            let _ = error_lut_bank(&mut nl, tables_for(w), false, &f1, &f2);
            let after = crate::fabric::area::report(&nl).luts;
            assert_eq!(after - before, w, "w={w}");
        }
    }

    #[test]
    fn error_lut_bank_values_match_table() {
        use crate::arith::table::{tables_for, CorrectionTables};
        let t = tables_for(8);
        let mut nl = Netlist::new();
        let f1 = nl.input("f1", 15);
        let f2 = nl.input("f2", 15);
        let bus = error_lut_bank(&mut nl, t, false, &f1, &f2);
        nl.output("c", &bus);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(4);
        for _ in 0..2_000 {
            let f1v = rng.below(1 << 15);
            let f2v = rng.below(1 << 15);
            let got = sim.run_single(&[("f1", f1v), ("f2", f2v)])[0].1;
            let c = t.mul[CorrectionTables::region(16, f1v)][CorrectionTables::region(16, f2v)];
            let want = CorrectionTables::scale_to_f(c, 16) as u64;
            assert_eq!(got, want, "f1={f1v:#x} f2={f2v:#x}");
        }
    }
}
