//! Small self-contained infrastructure: PRNG, statistics, property-test
//! helper. These replace `rand`, `statrs` and `proptest`, which are not
//! available in the offline vendored registry (see DESIGN.md §1).

pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;

/// Repository root: nearest ancestor holding `.git` (or `ROADMAP.md`),
/// falling back to the current directory. The tracked bench outputs
/// (`BENCH_hotpath.json`, `BENCH_serve.json`) land here so they are
/// comparable PR-over-PR regardless of the invocation directory.
pub fn repo_root() -> std::path::PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut dir = cwd.clone();
    loop {
        if dir.join(".git").exists() || dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return cwd;
        }
    }
}
