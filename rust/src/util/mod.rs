//! Small self-contained infrastructure: PRNG, statistics, property-test
//! helper. These replace `rand`, `statrs` and `proptest`, which are not
//! available in the offline vendored registry (see DESIGN.md §1).

pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
