//! Minimal property-testing helper (offline substitute for `proptest`).
//!
//! `check` runs a property over `cases` random inputs drawn by a generator
//! closure; on failure it performs a simple halving shrink over the raw seed
//! stream to report a small counterexample. This covers the invariant-style
//! properties this repo needs (coordinator routing/batching/state, arithmetic
//! bounds) without the full proptest dependency.

use super::rng::Rng;

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropFailure<T: std::fmt::Debug> {
    pub case: T,
    pub message: String,
    pub seed: u64,
}

/// Run `property` over `cases` inputs produced by `gen`.
///
/// Panics with the (shrunk) counterexample on failure, mirroring proptest's
/// ergonomics for use inside `#[test]` functions.
pub fn check<T, G, P>(seed: u64, cases: u32, mut gen: G, mut property: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let case_seed = rng.next_u64();
        let case = gen(&mut Rng::new(case_seed));
        if let Err(msg) = property(&case) {
            // Shrink: try a few derived seeds, keep the lexicographically
            // smallest failing debug representation (cheap but effective for
            // integer-heavy cases).
            let mut best = (format!("{case:?}"), case.clone(), msg.clone());
            for k in 0..64u64 {
                let s = case_seed.wrapping_shr((k % 63) as u32) ^ k;
                let cand = gen(&mut Rng::new(s));
                if let Err(m) = property(&cand) {
                    let d = format!("{cand:?}");
                    if d.len() < best.0.len() || (d.len() == best.0.len() && d < best.0) {
                        best = (d, cand, m);
                    }
                }
            }
            panic!(
                "property failed at case {i}/{cases} (seed {seed}): {}\ncounterexample: {}",
                best.2, best.0
            );
        }
    }
}

/// Convenience: property over pairs of N-bit operands (both non-zero).
pub fn check_operand_pairs<P>(seed: u64, cases: u32, bits: u32, mut property: P)
where
    P: FnMut(u64, u64) -> Result<(), String>,
{
    check(
        seed,
        cases,
        |r| (r.operand(bits), r.operand(bits)),
        |&(a, b)| property(a, b),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |r| r.below(100), |&x| {
            if x < 100 { Ok(()) } else { Err(format!("{x} out of range")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        check(2, 200, |r| r.below(100), |&x| {
            if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) }
        });
    }

    #[test]
    fn operand_pairs_nonzero() {
        check_operand_pairs(3, 500, 16, |a, b| {
            if a == 0 || b == 0 {
                Err("zero operand".into())
            } else {
                Ok(())
            }
        });
    }
}
