//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every experiment in this repository is seeded, so tables/figures are
//! reproducible run-to-run. The generator is the reference xoshiro256**
//! (Blackman & Vigna), which is more than adequate for workload generation
//! and Monte-Carlo error estimation.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform value in `[0, bound)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        // 128-bit multiply rejection sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform value in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal variate (Box–Muller; one value per call).
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform non-zero N-bit operand (valid multiplier/divider input).
    #[inline]
    pub fn operand(&mut self, bits: u32) -> u64 {
        self.range(1, (1u64 << bits) - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(4);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range(5, 8);
            assert!((5..=8).contains(&v));
            saw_lo |= v == 5;
            saw_hi |= v == 8;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn operand_nonzero_and_in_width() {
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            let v = r.operand(8);
            assert!(v >= 1 && v <= 255);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(8);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
