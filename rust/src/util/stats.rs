//! Streaming statistics used by the error evaluators and bench harness.

/// Online summary: count / mean / min / max / variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a (sortable) sample buffer. `q` in [0,1].
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() - 1) as f64 * q).round() as usize;
    samples[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut whole = Summary::new();
        for &x in &xs {
            whole.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..37] {
            a.add(x);
        }
        for &x in &xs[37..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_endpoints() {
        let mut v = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&mut v, 0.0), 1.0);
        assert_eq!(percentile(&mut v, 1.0), 5.0);
        assert_eq!(percentile(&mut v, 0.5), 3.0);
    }

    #[test]
    fn empty_summary_is_sane() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
    }
}
