//! ANN substrate for Table 4: a fully-connected network (784 → 100 [→ 100]
//! → 10, as in the paper's MNIST-CNN-derived MLP [1]) trained in floating
//! point, then quantized to 8-bit fixed point for inference where every
//! weight×activation product routes through a pluggable
//! [`Engine`] — accurate, SIMDive, or MBM behind the one execution seam
//! (DESIGN.md §10).
//!
//! Training runs either here (self-contained, used by the Table-4 bench)
//! or in `python/compile/train.py` (for the PJRT serving artifacts); both
//! consume the same synthetic datasets ([`crate::datasets`]).

use crate::datasets::{Example, CLASSES, IMG};
use crate::engine::Engine;
use crate::util::Rng;

/// Float MLP: weights `w[l]` are `[out × in]` row-major.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub dims: Vec<usize>,
    pub w: Vec<Vec<f32>>,
    pub b: Vec<Vec<f32>>,
}

impl Mlp {
    /// He-initialized network with the given hidden layout.
    pub fn new(hidden: &[usize], seed: u64) -> Self {
        let mut dims = vec![IMG * IMG];
        dims.extend_from_slice(hidden);
        dims.push(CLASSES);
        let mut rng = Rng::new(seed);
        let mut w = Vec::new();
        let mut b = Vec::new();
        for l in 0..dims.len() - 1 {
            let (fan_in, fan_out) = (dims[l], dims[l + 1]);
            let std = (2.0 / fan_in as f64).sqrt();
            w.push((0..fan_in * fan_out).map(|_| (rng.normal() * std) as f32).collect());
            b.push(vec![0f32; fan_out]);
        }
        Mlp { dims, w, b }
    }

    /// Forward pass in f32; returns all layer activations (post-ReLU for
    /// hidden, raw logits for the last layer).
    pub fn forward(&self, input: &[f32]) -> Vec<Vec<f32>> {
        let mut acts = vec![input.to_vec()];
        for l in 0..self.w.len() {
            let (fan_in, fan_out) = (self.dims[l], self.dims[l + 1]);
            let mut out = vec![0f32; fan_out];
            let x = &acts[l];
            for o in 0..fan_out {
                let row = &self.w[l][o * fan_in..(o + 1) * fan_in];
                let mut s = self.b[l][o];
                for i in 0..fan_in {
                    s += row[i] * x[i];
                }
                out[o] = if l + 1 < self.w.len() { s.max(0.0) } else { s };
            }
            acts.push(out);
        }
        acts
    }

    pub fn predict(&self, input: &[f32]) -> usize {
        let acts = self.forward(input);
        argmax_f32(acts.last().unwrap())
    }

    /// Minibatch SGD with softmax cross-entropy and 1/(1+e/2) lr decay.
    pub fn train(&mut self, data: &[Example], epochs: usize, lr0: f32, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        for epoch in 0..epochs {
            let lr = lr0 / (1.0 + 0.5 * epoch as f32);
            rng.shuffle(&mut order);
            for &idx in &order {
                let ex = &data[idx];
                let input: Vec<f32> = ex.pixels.iter().map(|&p| p as f32 / 255.0).collect();
                let acts = self.forward(&input);
                // Softmax grad at output.
                let logits = acts.last().unwrap();
                let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = logits.iter().map(|&v| (v - maxl).exp()).collect();
                let sum: f32 = exps.iter().sum();
                let mut delta: Vec<f32> =
                    exps.iter().map(|&e| e / sum).collect();
                delta[ex.label as usize] -= 1.0;
                // Backprop.
                for l in (0..self.w.len()).rev() {
                    let (fan_in, fan_out) = (self.dims[l], self.dims[l + 1]);
                    let x = &acts[l];
                    let mut prev_delta = vec![0f32; fan_in];
                    for o in 0..fan_out {
                        let d = delta[o];
                        if d != 0.0 {
                            let row = &mut self.w[l][o * fan_in..(o + 1) * fan_in];
                            for i in 0..fan_in {
                                prev_delta[i] += row[i] * d;
                                row[i] -= lr * d * x[i];
                            }
                            self.b[l][o] -= lr * d;
                        }
                    }
                    if l > 0 {
                        // ReLU mask.
                        for i in 0..fan_in {
                            if acts[l][i] <= 0.0 {
                                prev_delta[i] = 0.0;
                            }
                        }
                    }
                    delta = prev_delta;
                }
            }
        }
    }

    /// Float accuracy over a test set.
    pub fn accuracy(&self, data: &[Example]) -> f64 {
        let correct = data
            .iter()
            .filter(|ex| {
                let input: Vec<f32> = ex.pixels.iter().map(|&p| p as f32 / 255.0).collect();
                self.predict(&input) == ex.label as usize
            })
            .count();
        correct as f64 / data.len() as f64
    }
}

fn argmax_f32(v: &[f32]) -> usize {
    v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
}

/// 8-bit post-training-quantized network (paper §4.3: parameters and
/// activations quantized to 8-bit fixed point for inference).
#[derive(Clone, Debug)]
pub struct QuantMlp {
    pub dims: Vec<usize>,
    /// Per-layer signed 8-bit weights.
    pub w_q: Vec<Vec<i8>>,
    /// Per-layer bias in accumulator units.
    pub b_q: Vec<Vec<i64>>,
    /// Per-layer requantization multiplier accumulator → u8 activation.
    pub requant: Vec<f32>,
}

impl QuantMlp {
    /// Quantize a trained float net, calibrating activation scales on
    /// `calib` examples.
    pub fn from_float(net: &Mlp, calib: &[Example]) -> Self {
        let layers = net.w.len();
        // Per-layer activation max from calibration (f32 forward).
        let mut act_max = vec![0f32; layers + 1];
        act_max[0] = 1.0; // inputs are /255
        for ex in calib {
            let input: Vec<f32> = ex.pixels.iter().map(|&p| p as f32 / 255.0).collect();
            let acts = net.forward(&input);
            for l in 1..=layers {
                for &v in &acts[l] {
                    if v > act_max[l] {
                        act_max[l] = v;
                    }
                }
            }
        }
        let mut w_q = Vec::new();
        let mut b_q = Vec::new();
        let mut requant = Vec::new();
        for l in 0..layers {
            let wmax = net.w[l].iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-6);
            let sw = 127.0 / wmax;
            let sa = 255.0 / act_max[l].max(1e-6); // activation scale into u8
            w_q.push(
                net.w[l].iter().map(|&v| (v * sw).round().clamp(-127.0, 127.0) as i8).collect(),
            );
            b_q.push(net.b[l].iter().map(|&v| (v * sw * sa) as i64).collect());
            // acc units = value · sw · sa ; next activation u8 = value ·
            // sa_next ⇒ requant = sa_next / (sw · sa).
            let sa_next = 255.0 / act_max[l + 1].max(1e-6);
            requant.push(sa_next / (sw * sa));
        }
        QuantMlp { dims: net.dims.clone(), w_q, b_q, requant }
    }

    /// Quantized forward pass with a pluggable 8-bit multiplier. Products
    /// are `|w| × a` through the engine's multiplier design (both operands
    /// 8-bit unsigned, as in the SIMDive lane), signs re-applied,
    /// accumulation exact.
    ///
    /// The weight×activation products of a whole layer are gathered into
    /// operand slices and evaluated through one [`Engine::mul_into`] call
    /// (the engine seam, DESIGN.md §10) instead of one scalar dispatch per
    /// weight — the per-neuron skip of zero operands and the accumulation
    /// order are unchanged, so results are bit-identical to the scalar
    /// path for every backend.
    pub fn predict(&self, pixels: &[u8], engine: &Engine) -> usize {
        let layers = self.w_q.len();
        let mut act: Vec<u8> = pixels.to_vec();
        // Reusable per-layer gather buffers (operands, signs, row bounds).
        let mut ops_w: Vec<u64> = Vec::new();
        let mut ops_a: Vec<u64> = Vec::new();
        let mut neg: Vec<bool> = Vec::new();
        let mut row_end: Vec<usize> = Vec::new();
        let mut prods: Vec<u64> = Vec::new();
        for l in 0..layers {
            let (fan_in, fan_out) = (self.dims[l], self.dims[l + 1]);
            ops_w.clear();
            ops_a.clear();
            neg.clear();
            row_end.clear();
            for o in 0..fan_out {
                let row = &self.w_q[l][o * fan_in..(o + 1) * fan_in];
                for i in 0..fan_in {
                    let a = act[i] as u64;
                    if a == 0 || row[i] == 0 {
                        continue;
                    }
                    ops_w.push(row[i].unsigned_abs() as u64);
                    ops_a.push(a);
                    neg.push(row[i] < 0);
                }
                row_end.push(ops_w.len());
            }
            engine.mul_into(8, &ops_w, &ops_a, &mut prods);
            let mut next = vec![0u8; fan_out];
            let mut logits = vec![0i64; fan_out];
            let mut start = 0usize;
            for o in 0..fan_out {
                let end = row_end[o];
                let mut acc = self.b_q[l][o];
                for k in start..end {
                    let p = prods[k] as i64;
                    acc += if neg[k] { -p } else { p };
                }
                start = end;
                if l + 1 < layers {
                    let v = (acc.max(0) as f32 * self.requant[l]).round();
                    next[o] = v.clamp(0.0, 255.0) as u8;
                } else {
                    logits[o] = acc;
                }
            }
            if l + 1 < layers {
                act = next;
            } else {
                return logits
                    .iter()
                    .enumerate()
                    .max_by_key(|&(_, &v)| v)
                    .unwrap()
                    .0;
            }
        }
        unreachable!()
    }

    /// Accuracy with the given engine.
    pub fn accuracy(&self, data: &[Example], engine: &Engine) -> f64 {
        let correct =
            data.iter().filter(|ex| self.predict(&ex.pixels, engine) == ex.label as usize).count();
        correct as f64 / data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::MulDesign;
    use crate::datasets::{generate, Family};

    fn small_net(family: Family) -> (Mlp, Vec<Example>, Vec<Example>) {
        let train = generate(family, 1200, 101);
        let test = generate(family, 300, 102);
        let mut net = Mlp::new(&[32], 7);
        net.train(&train, 3, 0.05, 8);
        (net, train, test)
    }

    #[test]
    fn float_training_learns_digits() {
        let (net, _, test) = small_net(Family::Digits);
        let acc = net.accuracy(&test);
        assert!(acc > 0.75, "float accuracy {acc}");
    }

    #[test]
    fn quantized_accurate_close_to_float() {
        let (net, train, test) = small_net(Family::Digits);
        let q = QuantMlp::from_float(&net, &train[..200]);
        let fa = net.accuracy(&test);
        let qa = q.accuracy(&test, &Engine::from_mul(MulDesign::Accurate));
        assert!(qa > fa - 0.08, "float {fa} vs quant {qa}");
    }

    #[test]
    fn simdive_matches_accurate_quantized() {
        // Table 4's key claim: SIMDive inference accuracy ≈ accurate 8-bit
        // (± noise), thanks to ANN error resilience.
        let (net, train, test) = small_net(Family::Digits);
        let q = QuantMlp::from_float(&net, &train[..200]);
        let qa = q.accuracy(&test, &Engine::from_mul(MulDesign::Accurate));
        let qs = q.accuracy(&test, &Engine::from_mul(MulDesign::Simdive { w: 8 }));
        let qm = q.accuracy(&test, &Engine::from_mul(MulDesign::Mbm));
        assert!((qa - qs).abs() < 0.05, "accurate {qa} vs simdive {qs}");
        assert!((qa - qm).abs() < 0.08, "accurate {qa} vs mbm {qm}");
    }

    /// Reference scalar forward pass (one `design.mul` dispatch per
    /// weight) — the pre-engine hot path, kept as the equivalence oracle.
    fn scalar_predict(q: &QuantMlp, pixels: &[u8], design: MulDesign) -> usize {
        let layers = q.w_q.len();
        let mut act: Vec<u8> = pixels.to_vec();
        for l in 0..layers {
            let (fan_in, fan_out) = (q.dims[l], q.dims[l + 1]);
            let mut next = vec![0u8; fan_out];
            let mut logits = vec![0i64; fan_out];
            for o in 0..fan_out {
                let row = &q.w_q[l][o * fan_in..(o + 1) * fan_in];
                let mut acc = q.b_q[l][o];
                for i in 0..fan_in {
                    let a = act[i] as u64;
                    if a == 0 || row[i] == 0 {
                        continue;
                    }
                    let p = design.mul(8, row[i].unsigned_abs() as u64, a) as i64;
                    acc += if row[i] < 0 { -p } else { p };
                }
                if l + 1 < layers {
                    let v = (acc.max(0) as f32 * q.requant[l]).round();
                    next[o] = v.clamp(0.0, 255.0) as u8;
                } else {
                    logits[o] = acc;
                }
            }
            if l + 1 < layers {
                act = next;
            } else {
                return logits.iter().enumerate().max_by_key(|&(_, &v)| v).unwrap().0;
            }
        }
        unreachable!()
    }

    #[test]
    fn batched_inference_matches_scalar_reference() {
        let (net, train, test) = small_net(Family::Digits);
        let q = QuantMlp::from_float(&net, &train[..200]);
        for design in [MulDesign::Simdive { w: 8 }, MulDesign::Accurate, MulDesign::Mbm] {
            let engine = Engine::from_mul(design);
            for ex in &test[..60] {
                assert_eq!(
                    q.predict(&ex.pixels, &engine),
                    scalar_predict(&q, &ex.pixels, design),
                    "design {}",
                    design.name()
                );
            }
        }
    }

    #[test]
    fn inference_is_backend_invariant() {
        // The engine-seam contract holds end to end: reference, batched
        // and sharded backends classify every example identically.
        let (net, train, test) = small_net(Family::Digits);
        let q = QuantMlp::from_float(&net, &train[..200]);
        let design = MulDesign::Simdive { w: 8 };
        let batched = Engine::from_mul(design);
        let reference = Engine::reference(design, crate::arith::DivDesign::Accurate);
        let sharded = Engine::sharded(
            design,
            crate::arith::DivDesign::Accurate,
            crate::engine::ShardedConfig { shards: 2, queue_depth: 256, batch: 32 },
        );
        for ex in &test[..20] {
            let want = q.predict(&ex.pixels, &reference);
            assert_eq!(q.predict(&ex.pixels, &batched), want);
            assert_eq!(q.predict(&ex.pixels, &sharded), want);
        }
    }

    #[test]
    fn fashion_trains_too() {
        let (net, train, test) = small_net(Family::Fashion);
        let q = QuantMlp::from_float(&net, &train[..200]);
        let qa = q.accuracy(&test, &Engine::from_mul(MulDesign::Accurate));
        assert!(qa > 0.6, "fashion quant accuracy {qa}");
    }
}
