//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO **text**,
//! per the 64-bit-proto-id workaround — see /opt/xla-example/README.md and
//! DESIGN.md §2) and executes them on the CPU PJRT client from the request
//! path. Python never runs at serve time.
//!
//! The `xla` bindings are not present in the offline vendored registry, so
//! the PJRT-backed engine is gated behind the `pjrt` cargo feature
//! (DESIGN.md §2). The default build compiles a stub engine with the same
//! artifact-discovery and weight-loading surface; `run` reports the backend
//! as unavailable instead of executing.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded model artifact bundle (PJRT-backed build).
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    weights: HashMap<String, Vec<f32>>,
    manifest: Vec<(String, Vec<usize>)>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create a CPU engine and load every `*.hlo.txt` in `dir`, plus any
    /// `weights.bin` + `weights.manifest` pair (flat f32 tensors).
    pub fn load(dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for name in hlo_artifact_names(dir)? {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            executables.insert(name, exe);
        }
        let (weights, manifest) = load_weights(dir)?;
        Ok(Engine { client, executables, weights, manifest })
    }

    /// Artifact names available.
    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.executables.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute a model on literal inputs; returns the tuple elements (the
    /// AOT pipeline lowers everything with `return_tuple=True`).
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("unknown model {name}; have {:?}", self.models()))?;
        let mut result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.decompose_tuple()?)
    }

    /// A named weight tensor (flat) from the artifact bundle.
    pub fn weight(&self, name: &str) -> Option<&[f32]> {
        self.weights.get(name).map(|v| v.as_slice())
    }

    /// Weight manifest (name, shape) in file order.
    pub fn weight_manifest(&self) -> &[(String, Vec<usize>)] {
        &self.manifest
    }
}

/// A loaded model artifact bundle (stub build, no PJRT backend).
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    models: Vec<String>,
    weights: HashMap<String, Vec<f32>>,
    manifest: Vec<(String, Vec<usize>)>,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Discover `*.hlo.txt` artifacts and load weight tensors. Execution is
    /// unavailable in this build; see the module docs.
    pub fn load(dir: &Path) -> Result<Engine> {
        let mut models = hlo_artifact_names(dir)?;
        models.sort();
        let (weights, manifest) = load_weights(dir)?;
        Ok(Engine { models, weights, manifest })
    }

    /// Artifact names available.
    pub fn models(&self) -> Vec<String> {
        self.models.clone()
    }

    pub fn platform(&self) -> String {
        "stub (built without the pjrt feature)".to_string()
    }

    /// Always errors: the PJRT backend is compiled out of this build. The
    /// generic input parameter keeps call sites compiling in both builds
    /// (the pjrt build takes `&[xla::Literal]`).
    pub fn run<T>(&self, name: &str, _inputs: &[T]) -> Result<Vec<f32>> {
        anyhow::bail!(
            "cannot execute model {name}: PJRT backend unavailable \
             (rebuild with `--features pjrt` and a vendored xla crate)"
        )
    }

    /// A named weight tensor (flat) from the artifact bundle.
    pub fn weight(&self, name: &str) -> Option<&[f32]> {
        self.weights.get(name).map(|v| v.as_slice())
    }

    /// Weight manifest (name, shape) in file order.
    pub fn weight_manifest(&self) -> &[(String, Vec<usize>)] {
        &self.manifest
    }
}

/// Stems of every `*.hlo.txt` artifact in `dir`.
fn hlo_artifact_names(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("reading artifacts dir {}", dir.display()))?
    {
        let path = entry?.path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if let Some(stem) = name.strip_suffix(".hlo.txt") {
            names.push(stem.to_string());
        }
    }
    Ok(names)
}

/// Load `weights.manifest` ("name dim0 dim1 …" per line) + `weights.bin`
/// (concatenated little-endian f32).
fn load_weights(dir: &Path) -> Result<(HashMap<String, Vec<f32>>, Vec<(String, Vec<usize>)>)> {
    let manifest_path = dir.join("weights.manifest");
    let bin_path = dir.join("weights.bin");
    let mut map = HashMap::new();
    let mut manifest = Vec::new();
    if !manifest_path.exists() || !bin_path.exists() {
        return Ok((map, manifest));
    }
    let text = std::fs::read_to_string(&manifest_path)?;
    let raw = std::fs::read(&bin_path)?;
    let mut offset = 0usize;
    for line in text.lines() {
        let mut parts = line.split_whitespace();
        let Some(name) = parts.next() else { continue };
        let dims: Vec<usize> = parts.map(|p| p.parse().unwrap_or(0)).collect();
        let count: usize = dims.iter().product();
        anyhow::ensure!(offset + 4 * count <= raw.len(), "weights.bin too short at {name}");
        let bytes = &raw[offset..offset + 4 * count];
        let vals: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        offset += 4 * count;
        manifest.push((name.to_string(), dims));
        map.insert(name.to_string(), vals);
    }
    Ok((map, manifest))
}

/// Default artifacts directory (`artifacts/` beside the workspace, or
/// `$SIMDIVE_ARTIFACTS`).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SIMDIVE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_errors_cleanly() {
        let err = match Engine::load(Path::new("/nonexistent/simdive")) {
            Err(e) => e,
            Ok(_) => panic!("load must fail on a missing dir"),
        };
        assert!(format!("{err:#}").contains("artifacts dir"));
    }

    #[test]
    fn weights_loader_handles_absent_files() {
        let dir = std::env::temp_dir().join("simdive_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let (w, m) = load_weights(&dir).unwrap();
        assert!(w.is_empty() && m.is_empty());
    }

    #[test]
    fn weights_roundtrip() {
        let dir = std::env::temp_dir().join("simdive_rt_weights");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("weights.manifest"), "w1 2 3\nb1 3\n").unwrap();
        let vals: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("weights.bin"), bytes).unwrap();
        let (w, m) = load_weights(&dir).unwrap();
        assert_eq!(w["w1"], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(w["b1"], vec![6.0, 7.0, 8.0]);
        assert_eq!(m[0].1, vec![2, 3]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_reports_backend_unavailable() {
        let dir = std::env::temp_dir().join("simdive_rt_stub");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("demo.hlo.txt"), "HloModule demo").unwrap();
        let eng = Engine::load(&dir).unwrap();
        assert!(eng.models().contains(&"demo".to_string()));
        let err = eng.run("demo", &[0i32]).unwrap_err();
        assert!(format!("{err}").contains("PJRT backend unavailable"));
    }
}
