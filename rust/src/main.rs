//! `simdive` — CLI entry point for the SIMDive reproduction.
//!
//! Subcommands regenerate each paper table/figure (DESIGN.md §5), export
//! golden vectors for the Python layer, run the SIMD-wire network server
//! (`serve --listen`), and drive one (`loadgen`) — DESIGN.md §8.

use simdive::report;

fn usage() -> ! {
    eprintln!(
        "usage: simdive <command> [args]\n\
         commands:\n\
         \ttable2 [--samples N]   SISD multiplier/divider metrics (Table 2)\n\
         \ttable3                 32-bit SIMD metrics (Table 3)\n\
         \ttable4 [--fast]        ANN accuracy (Table 4)\n\
         \tfig1                   Mitchell error heat maps (Fig. 1)\n\
         \tfig3                   image blending PSNR (Fig. 3)\n\
         \tfig4                   Gaussian smoothing PSNR (Fig. 4)\n\
         \ttunable [--samples N]  accuracy-vs-w sweep (§3.3)\n\
         \texport-golden          golden vectors for python tests\n\
         \tdemo                   quick SIMD coordinator demo\n\
         \tprofile                error-profile table driving the budget router (§9)\n\
         \tserve --listen ADDR [--workers N] [--window K] [--batch B]\n\
         \t      [--deadline-ms D] [--io-timeout-ms T]\n\
         \t      [--loops N | --threaded]\n\
         \t      [--fault-ppm P --fault-seed S]\n\
         \t                       SIMD-wire TCP server over the shared coordinator\n\
         \t                       (reactor backend with N event loops by default,\n\
         \t                       --threaded for thread-per-connection;\n\
         \t                       --fault-ppm enables the chaos injector, §11)\n\
         \tloadgen --addr ADDR [--connections C] [--requests N] [--chunk B]\n\
         \t        [--mix 8,8,16,32] [--w N | --budget-ppm E] [--out PATH]\n\
         \t        [--sweep]\n\
         \t                       drive a server; writes BENCH_serve.json\n\
         \t                       (--sweep appends a reactor-vs-threaded\n\
         \t                       connections_sweep over fresh loopback servers)\n\
         \tloadgen --chaos --addr ADDR [--connections C] [--requests N]\n\
         \t        [--chunk B] [--seed S]\n\
         \t                       chaos scenario: verified traffic + saboteur;\n\
         \t                       exits non-zero on any invariant violation\n\
         \tstats --addr ADDR [--watch SECS] [--check]\n\
         \t                       STATS2 registry snapshot: stage histograms,\n\
         \t                       shard gauges, tier counters (--check exits\n\
         \t                       non-zero unless every stage/shard reported)\n\
         \ttrace --addr ADDR [--chrome] [--out PATH]\n\
         \t                       drain the sampled trace ring as JSONL\n\
         \t                       (or chrome://tracing JSON with --chrome)\n\
         \tnetlist-check [--design mul|div|all] [--bits 8|16|32|all]\n\
         \t              [--report [--out PATH]]\n\
         \t                       structural lint + cone/critical-path sweep\n\
         \t                       over the generated designs; --report writes\n\
         \t                       BENCH_fabric.json; exits non-zero on lint errors\n\
         \tall                    every table + figure in sequence"
    );
    std::process::exit(2)
}

fn arg_u64(args: &[String], name: &str, default: u64) -> u64 {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn arg_str<'a>(args: &'a [String], name: &str, default: &'a str) -> &'a str {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or(default)
}

/// Strict integer flag: absent → `None`, present-but-unparsable → error
/// (the serve/loadgen flags feed CI and bench scripts, where a typo must
/// fail loudly rather than fall back to a plausible default).
fn arg_u64_opt(args: &[String], name: &str) -> anyhow::Result<Option<u64>> {
    match args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)) {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| anyhow::anyhow!("{name} expects an integer (got '{v}')")),
    }
}

/// Strict integer flag with a default.
fn arg_u64_strict(args: &[String], name: &str, default: u64) -> anyhow::Result<u64> {
    Ok(arg_u64_opt(args, name)?.unwrap_or(default))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("");
    match cmd {
        "table2" => {
            let samples = arg_u64(&args, "--samples", report::table2::ERROR_SAMPLES);
            println!("{}", report::table2::render(samples));
        }
        "table3" => println!("{}", report::table3::render()),
        "table4" => {
            let scale = if args.iter().any(|a| a == "--fast") {
                report::table4::Scale { train: 1500, test: 300, epochs: 3, nodes: 48 }
            } else {
                report::table4::Scale::default()
            };
            println!("{}", report::table4::render(scale));
        }
        "fig1" => println!("{}", report::figs::fig1()?),
        "fig3" => println!("{}", report::figs::fig3()?),
        "fig4" => println!("{}", report::figs::fig4()?),
        "tunable" => {
            let samples = arg_u64(&args, "--samples", 300_000);
            println!("{}", report::tunable::render(samples));
        }
        "export-golden" => println!("{}", report::golden::export()?),
        "demo" => demo(),
        "profile" => profile(),
        "serve" => serve(&args)?,
        "loadgen" => loadgen(&args)?,
        "stats" => stats_cmd(&args)?,
        "trace" => trace_cmd(&args)?,
        "netlist-check" => netlist_check(&args)?,
        "all" => {
            let samples = arg_u64(&args, "--samples", report::table2::ERROR_SAMPLES);
            println!("{}", report::table2::render(samples));
            println!("{}", report::table3::render());
            println!("{}", report::table4::render(report::table4::Scale::default()));
            println!("{}", report::figs::fig1()?);
            println!("{}", report::figs::fig3()?);
            println!("{}", report::figs::fig4()?);
            println!("{}", report::tunable::render(300_000));
            println!("{}", report::golden::export()?);
        }
        "" => usage(),
        other => {
            eprintln!("error: unknown subcommand '{other}'\n");
            usage()
        }
    }
    Ok(())
}

/// Quick demonstration of the paper's running example + SIMD packing.
fn demo() {
    use simdive::arith::{exact, mitchell, simdive as sd};
    println!("SIMDive demo — paper running example (43 × 10, 43 ÷ 10):");
    println!("  exact    : {} , {}", exact::mul(8, 43, 10), exact::div(8, 43, 10));
    println!("  mitchell : {} , {}", mitchell::mul(8, 43, 10), mitchell::div(8, 43, 10));
    println!("  simdive  : {} , {}", sd::simdive_mul(8, 43, 10), sd::simdive_div(8, 43, 10));
    use simdive::coordinator::{Coordinator, CoordinatorConfig, ReqOp, Request};
    let coord = Coordinator::start(CoordinatorConfig::default());
    let mut handles = Vec::new();
    for i in 0..16u64 {
        handles.push(coord.submit(Request {
            id: i,
            op: if i % 3 == 0 { ReqOp::Div } else { ReqOp::Mul },
            bits: [8, 16, 32][(i % 3) as usize],
            w: (i % 9) as u32,
            a: 40 + i,
            b: 3 + i,
        }));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.recv().unwrap();
        println!("  req {i}: {}", r.value);
    }
    let s = coord.shutdown();
    println!(
        "coordinator: {} reqs in {} words, lane utilization {:.0}%, energy {:.1} nJ",
        s.requests,
        s.words,
        s.lane_utilization() * 100.0,
        s.energy_pj / 1000.0
    );
}

/// `profile`: print the measured `{op, width, w} → MRED` table the
/// error-budget router picks from (DESIGN.md §9), with an example routing
/// column.
fn profile() {
    use simdive::arith::{W_MAX, WIDTHS};
    use simdive::coordinator::{ErrorProfile, ReqOp};
    let p = ErrorProfile::get();
    println!("error profile (MRED, % — mean relative error vs exact):");
    println!("{:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "w", "mul8", "mul16", "mul32", "div8", "div16", "div32");
    for w in 0..=W_MAX {
        let cell = |op, bits| p.mred_ppm(op, bits, w) as f64 / 10_000.0;
        println!(
            "{w:>4} {:>11.3}% {:>11.3}% {:>11.3}% {:>11.3}% {:>11.3}% {:>11.3}%",
            cell(ReqOp::Mul, 8),
            cell(ReqOp::Mul, 16),
            cell(ReqOp::Mul, 32),
            cell(ReqOp::Div, 8),
            cell(ReqOp::Div, 16),
            cell(ReqOp::Div, 32),
        );
    }
    println!("\nbudget routing examples (cheapest w meeting the budget):");
    for budget_pct in [5.0f64, 3.0, 2.0, 1.5, 1.2] {
        let ppm = (budget_pct * 10_000.0) as u32;
        let picks: Vec<String> = WIDTHS
            .iter()
            .map(|&bits| format!("mul{bits}→w{}", p.pick_w(ReqOp::Mul, bits, ppm)))
            .chain(
                WIDTHS
                    .iter()
                    .map(|&bits| format!("div{bits}→w{}", p.pick_w(ReqOp::Div, bits, ppm))),
            )
            .collect();
        println!("  ≤{budget_pct}% ({ppm} ppm): {}", picks.join(", "));
    }
}

/// `serve --listen ADDR`: run the SIMD-wire TCP server over the
/// coordinator until the process is killed (DESIGN.md §8). Replaces the
/// old in-process serving demo — drive it with `simdive loadgen`.
fn serve(args: &[String]) -> anyhow::Result<()> {
    use simdive::serve::{ReactorOptions, ServeConfig, Server};
    let listen = arg_str(args, "--listen", "127.0.0.1:7171");
    let defaults = ServeConfig::default();
    let threaded = args.iter().any(|a| a == "--threaded");
    let loops = arg_u64_strict(args, "--loops", 0)? as usize;
    anyhow::ensure!(
        !(threaded && loops > 0),
        "--threaded and --loops are mutually exclusive"
    );
    let fault_ppm = arg_u64_strict(args, "--fault-ppm", 0)?;
    anyhow::ensure!(fault_ppm <= 1_000_000, "--fault-ppm must be 0..=1000000");
    let fault_seed = arg_u64_strict(args, "--fault-seed", 0xC4A05)?;
    let faults = (fault_ppm > 0)
        .then(|| simdive::faults::FaultConfig::server_chaos(fault_seed, fault_ppm as u32));
    let cfg = ServeConfig {
        workers: arg_u64_strict(args, "--workers", defaults.workers as u64)? as usize,
        window: arg_u64_strict(args, "--window", defaults.window as u64)? as usize,
        batch: arg_u64_strict(args, "--batch", defaults.batch as u64)? as usize,
        queue_depth: arg_u64_strict(args, "--queue-depth", defaults.queue_depth as u64)? as usize,
        deadline_ms: arg_u64_strict(args, "--deadline-ms", defaults.deadline_ms)?,
        io_timeout_ms: arg_u64_strict(args, "--io-timeout-ms", defaults.io_timeout_ms)?,
        faults,
    };
    if faults.is_some() {
        // Injected shard panics are part of the plan — keep them off
        // stderr (genuine panics still print).
        simdive::faults::silence_injected_panics();
    }
    // Warm the error-profile table before accepting traffic, so the first
    // budget-routed request doesn't stall its connection on the one-time
    // ~2M-evaluation measurement (DESIGN.md §9).
    simdive::coordinator::ErrorProfile::get();
    let server = if threaded {
        Server::start_threaded(listen, cfg)
    } else {
        Server::start_reactor(listen, cfg, ReactorOptions { loops, ..ReactorOptions::default() })
    }
    .map_err(|e| anyhow::anyhow!("cannot listen on {listen}: {e}"))?;
    println!(
        "simdive serve: listening on {} ({}, workers/w {}, window {}, batch {}, \
         deadline {} ms, io timeout {} ms, fault {} ppm)",
        server.local_addr(),
        if threaded {
            "thread-per-connection".to_string()
        } else {
            format!("reactor, {} threads", server.thread_count())
        },
        cfg.workers,
        cfg.window,
        cfg.batch,
        cfg.deadline_ms,
        cfg.io_timeout_ms,
        fault_ppm
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `loadgen --addr ADDR`: drive a SIMD-wire server and write
/// `BENCH_serve.json` (schema `simdive-serve-v1`).
fn loadgen(args: &[String]) -> anyhow::Result<()> {
    use simdive::serve::loadgen::{self, LoadgenConfig};
    let addr = arg_str(args, "--addr", "127.0.0.1:7171").to_string();
    if args.iter().any(|a| a == "--chaos") {
        return loadgen_chaos(args, &addr);
    }
    let defaults = LoadgenConfig::default();
    let mix = arg_str(args, "--mix", "8,8,8,16,16,32");
    let widths: Vec<u32> = mix
        .split(',')
        .map(|s| s.trim().parse::<u32>())
        .collect::<Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("--mix must be a comma list of 8/16/32 (got '{mix}')"))?;
    anyhow::ensure!(
        !widths.is_empty() && widths.iter().all(|&w| matches!(w, 8 | 16 | 32)),
        "--mix must be a comma list of 8/16/32 (got '{mix}')"
    );
    // --w N pins the accuracy knob; absent, w is spread over 0..=8.
    // --budget-ppm E switches every request to error-budget routing.
    let fixed_w = arg_u64_opt(args, "--w")?;
    anyhow::ensure!(
        fixed_w.map_or(true, |w| w <= simdive::arith::W_MAX as u64),
        "--w must be 0..=8"
    );
    let budget_ppm = arg_u64_opt(args, "--budget-ppm")?;
    anyhow::ensure!(
        budget_ppm.map_or(true, |p| (1..=u32::MAX as u64).contains(&p)),
        "--budget-ppm must be 1..=4294967295 (parts per million of relative error)"
    );
    anyhow::ensure!(
        fixed_w.is_none() || budget_ppm.is_none(),
        "--w and --budget-ppm are mutually exclusive"
    );
    let cfg = LoadgenConfig {
        connections: arg_u64_strict(args, "--connections", defaults.connections as u64)? as usize,
        requests: arg_u64_strict(args, "--requests", defaults.requests)?,
        chunk: arg_u64_strict(args, "--chunk", defaults.chunk as u64)? as usize,
        widths,
        fixed_w: fixed_w.map(|w| w as u32),
        budget_ppm: budget_ppm.map(|p| p as u32),
        seed: arg_u64_strict(args, "--seed", defaults.seed)?,
        ..defaults
    };
    let report = loadgen::run(&addr, &cfg).map_err(|e| anyhow::anyhow!("loadgen: {e}"))?;
    let s = &report.server;
    println!(
        "loadgen: {} requests over {} connections in {:.3}s — {:.1} kreq/s\n\
         server: {} requests, {} words, lane util {:.0}%, energy {:.2} µJ, \
         p50 {} µs, p99 {} µs",
        report.requests,
        report.connections,
        report.wall_s,
        report.rps / 1e3,
        s.requests,
        s.words,
        s.lane_utilization() * 100.0,
        s.energy_pj() / 1e6,
        s.p50_us,
        s.p99_us
    );
    // In-process coordinator comparison (same figure as BENCH_hotpath.json).
    let coord_n = report.requests.clamp(1, 40_000);
    let coord_rps = loadgen::coordinator_batched_rps(coord_n);
    println!(
        "coordinator (in-process, batched): {:.1} kreq/s over {coord_n} requests",
        coord_rps / 1e3
    );
    // --sweep: reactor-vs-threaded connection ladder over fresh loopback
    // servers, appended to the document as `connections_sweep`.
    let sweep = if args.iter().any(|a| a == "--sweep") {
        let points = loadgen::run_connections_sweep();
        println!("connections sweep (fresh loopback servers):");
        for p in &points {
            if p.ok {
                println!(
                    "  {:>8} @{:>5} conns: {:>9.1} kreq/s, p50 {} µs, p99 {} µs, {} threads",
                    p.mode,
                    p.connections,
                    p.rps / 1e3,
                    p.p50_us,
                    p.p99_us,
                    p.threads
                );
            } else {
                println!("  {:>8} @{:>5} conns: failed/skipped", p.mode, p.connections);
            }
        }
        points
    } else {
        Vec::new()
    };
    let out_path = match arg_str(args, "--out", "") {
        "" => simdive::util::repo_root().join("BENCH_serve.json"),
        p => std::path::PathBuf::from(p),
    };
    let json = loadgen::to_json_full(&report, coord_n, coord_rps, &[], &sweep);
    std::fs::write(&out_path, &json)
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", out_path.display()))?;
    println!("wrote {}", out_path.display());
    Ok(())
}

/// `stats --addr ADDR`: fetch the wire-v4 `STATS2` registry snapshot and
/// render it — counters and gauges one per line, histograms as
/// `count/p50/p99` (DESIGN.md §12). `--watch SECS` re-polls forever;
/// `--check` exits non-zero unless every request stage histogram is
/// populated and at least one shard reported its gauges (the CI stats
/// smoke step calls this against a freshly loaded server).
fn stats_cmd(args: &[String]) -> anyhow::Result<()> {
    use simdive::obs::trace::STAGE_NAMES;
    use simdive::obs::Value;
    use simdive::serve::Client;
    use std::time::Duration;
    let addr = arg_str(args, "--addr", "127.0.0.1:7171").to_string();
    let check = args.iter().any(|a| a == "--check");
    let watch = arg_u64_opt(args, "--watch")?;
    let mut client = Client::connect_retry(addr.as_str(), Duration::from_secs(5))
        .map_err(|e| anyhow::anyhow!("cannot connect to {addr}: {e}"))?;
    loop {
        let snap = client.stats2().map_err(|e| anyhow::anyhow!("STATS2 fetch failed: {e}"))?;
        for (name, value) in &snap.entries {
            match value {
                Value::Counter(v) => println!("{name} = {v}"),
                Value::Gauge(v) => println!("{name} = {v}"),
                Value::Hist(h) => println!(
                    "{name} = count {} p50 {} µs p99 {} µs",
                    h.count(),
                    h.percentile_us(0.50),
                    h.percentile_us(0.99)
                ),
            }
        }
        if check {
            for stage in STAGE_NAMES {
                let populated = snap.hist(&format!("stage.{stage}")).is_some_and(|h| h.count() > 0);
                anyhow::ensure!(
                    populated,
                    "stats --check: stage.{stage} histogram missing or empty"
                );
            }
            anyhow::ensure!(
                snap.gauge("shard.0.queue_depth").is_some(),
                "stats --check: shard.0.queue_depth gauge missing"
            );
            println!("stats --check: all stage histograms populated, shard gauges present");
        }
        match watch {
            Some(secs) => {
                println!();
                std::thread::sleep(Duration::from_secs(secs.max(1)));
            }
            None => return Ok(()),
        }
    }
}

/// `trace --addr ADDR`: drain the server's sampled trace ring and render
/// it as JSONL (one event per line) or, with `--chrome`, as a
/// chrome://tracing JSON document (DESIGN.md §12).
fn trace_cmd(args: &[String]) -> anyhow::Result<()> {
    use simdive::obs::trace::{render_chrome, render_jsonl};
    use simdive::serve::Client;
    use std::time::Duration;
    let addr = arg_str(args, "--addr", "127.0.0.1:7171").to_string();
    let mut client = Client::connect_retry(addr.as_str(), Duration::from_secs(5))
        .map_err(|e| anyhow::anyhow!("cannot connect to {addr}: {e}"))?;
    let events = client.trace_events().map_err(|e| anyhow::anyhow!("TRACE fetch failed: {e}"))?;
    let rendered = if args.iter().any(|a| a == "--chrome") {
        render_chrome(&events)
    } else {
        render_jsonl(&events)
    };
    match arg_str(args, "--out", "") {
        "" => print!("{rendered}"),
        p => {
            std::fs::write(p, &rendered).map_err(|e| anyhow::anyhow!("cannot write {p}: {e}"))?;
            eprintln!("trace: {} sampled events -> {p}", events.len());
        }
    }
    Ok(())
}

/// `netlist-check`: run the static-analysis sweep (DESIGN.md §14) over
/// the generated designs and gate on lint *errors* (warnings — dead cells
/// a mapper would sweep, foldable LUTs — are reported as counts). With
/// `--report`, write the `BENCH_fabric.json` artifact CI commits.
fn netlist_check(args: &[String]) -> anyhow::Result<()> {
    let design = arg_str(args, "--design", "all");
    anyhow::ensure!(
        matches!(design, "mul" | "div" | "all"),
        "--design must be mul, div or all (got '{design}')"
    );
    let bits_list: Vec<u32> = match arg_str(args, "--bits", "all") {
        "all" => vec![8, 16, 32],
        "8" => vec![8],
        "16" => vec![16],
        "32" => vec![32],
        other => anyhow::bail!("--bits must be 8, 16, 32 or all (got '{other}')"),
    };
    let cal = simdive::fabric::calibrate::fitted();
    let rows = report::fabric::sweep(&bits_list, design, cal);
    print!("{}", report::fabric::render(&rows));
    let errors: usize = rows.iter().map(|r| r.lint_errors).sum();
    let warnings: usize = rows.iter().map(|r| r.lint_warnings).sum();
    println!(
        "netlist-check: {} designs, {} lint errors, {} warnings",
        rows.len(),
        errors,
        warnings
    );
    if args.iter().any(|a| a == "--report") {
        let out_path = match arg_str(args, "--out", "") {
            "" => simdive::util::repo_root().join("BENCH_fabric.json"),
            p => std::path::PathBuf::from(p),
        };
        let json = report::fabric::to_json(&rows);
        std::fs::write(&out_path, &json)
            .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", out_path.display()))?;
        println!("wrote {}", out_path.display());
    }
    anyhow::ensure!(errors == 0, "netlist-check: {errors} lint errors");
    Ok(())
}

/// `loadgen --chaos`: run the fault-injection scenario (DESIGN.md §11)
/// and fail loudly — non-zero exit — if any robustness invariant breaks.
fn loadgen_chaos(args: &[String], addr: &str) -> anyhow::Result<()> {
    use simdive::serve::chaos::{self, ChaosConfig};
    let defaults = ChaosConfig::default();
    let cfg = ChaosConfig {
        connections: arg_u64_strict(args, "--connections", defaults.connections as u64)? as usize,
        requests: arg_u64_strict(args, "--requests", defaults.requests)?,
        chunk: arg_u64_strict(args, "--chunk", defaults.chunk as u64)? as usize,
        seed: arg_u64_strict(args, "--seed", defaults.seed)?,
        ..defaults
    };
    let c = chaos::run(addr, &cfg).map_err(|e| anyhow::anyhow!("chaos run: {e}"))?;
    println!(
        "chaos: {} requests — {} completed, {} failed, {} reconnects, \
         {} saboteur rounds, {:.1} kreq/s in {:.3}s\n\
         server: shed {} (overload), failed {} (unavailable), \
         connections {} -> {} (baseline -> final)",
        c.requests,
        c.completed,
        c.failed,
        c.reconnects,
        c.saboteur_rounds,
        c.rps / 1e3,
        c.wall_s,
        c.server.shed_overload,
        c.server.failed_unavailable,
        c.baseline_connections,
        c.final_connections,
    );
    anyhow::ensure!(c.mismatches == 0, "invariant violated: {} bit-mismatched responses", c.mismatches);
    anyhow::ensure!(c.unresolved == 0, "invariant violated: {} requests never resolved", c.unresolved);
    anyhow::ensure!(
        c.final_connections <= c.baseline_connections,
        "invariant violated: connection leak ({} -> {})",
        c.baseline_connections,
        c.final_connections
    );
    println!("chaos: all invariants hold (no wrong answer, no hang, no leak)");
    Ok(())
}
