//! `repro` — CLI entry point for the SIMDive reproduction.
//!
//! Subcommands regenerate each paper table/figure (DESIGN.md §5), export
//! golden vectors for the Python layer, and run the serving demo.

use simdive::report;

fn usage() -> ! {
    eprintln!(
        "usage: repro <command> [args]\n\
         commands:\n\
         \ttable2 [--samples N]   SISD multiplier/divider metrics (Table 2)\n\
         \ttable3                 32-bit SIMD metrics (Table 3)\n\
         \ttable4 [--fast]        ANN accuracy (Table 4)\n\
         \tfig1                   Mitchell error heat maps (Fig. 1)\n\
         \tfig3                   image blending PSNR (Fig. 3)\n\
         \tfig4                   Gaussian smoothing PSNR (Fig. 4)\n\
         \ttunable [--samples N]  accuracy-vs-w sweep (§3.3)\n\
         \texport-golden          golden vectors for python tests\n\
         \tdemo                   quick SIMD coordinator demo\n\
         \tserve [--requests N]   batched serving demo through the coordinator\n\
         \tall                    every table + figure in sequence"
    );
    std::process::exit(2)
}

fn arg_u64(args: &[String], name: &str, default: u64) -> u64 {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("");
    match cmd {
        "table2" => {
            let samples = arg_u64(&args, "--samples", report::table2::ERROR_SAMPLES);
            println!("{}", report::table2::render(samples));
        }
        "table3" => println!("{}", report::table3::render()),
        "table4" => {
            let scale = if args.iter().any(|a| a == "--fast") {
                report::table4::Scale { train: 1500, test: 300, epochs: 3, nodes: 48 }
            } else {
                report::table4::Scale::default()
            };
            println!("{}", report::table4::render(scale));
        }
        "fig1" => println!("{}", report::figs::fig1()?),
        "fig3" => println!("{}", report::figs::fig3()?),
        "fig4" => println!("{}", report::figs::fig4()?),
        "tunable" => {
            let samples = arg_u64(&args, "--samples", 300_000);
            println!("{}", report::tunable::render(samples));
        }
        "export-golden" => println!("{}", report::golden::export()?),
        "demo" => demo(),
        "serve" => serve(arg_u64(&args, "--requests", 100_000)),
        "all" => {
            let samples = arg_u64(&args, "--samples", report::table2::ERROR_SAMPLES);
            println!("{}", report::table2::render(samples));
            println!("{}", report::table3::render());
            println!("{}", report::table4::render(report::table4::Scale::default()));
            println!("{}", report::figs::fig1()?);
            println!("{}", report::figs::fig3()?);
            println!("{}", report::figs::fig4()?);
            println!("{}", report::tunable::render(300_000));
            println!("{}", report::golden::export()?);
        }
        _ => usage(),
    }
    Ok(())
}

/// Quick demonstration of the paper's running example + SIMD packing.
fn demo() {
    use simdive::arith::{exact, mitchell, simdive as sd};
    println!("SIMDive demo — paper running example (43 × 10, 43 ÷ 10):");
    println!("  exact    : {} , {}", exact::mul(8, 43, 10), exact::div(8, 43, 10));
    println!("  mitchell : {} , {}", mitchell::mul(8, 43, 10), mitchell::div(8, 43, 10));
    println!("  simdive  : {} , {}", sd::simdive_mul(8, 43, 10), sd::simdive_div(8, 43, 10));
    use simdive::coordinator::{Coordinator, CoordinatorConfig, ReqOp, Request};
    let coord = Coordinator::start(CoordinatorConfig::default());
    let mut handles = Vec::new();
    for i in 0..16u64 {
        handles.push(coord.submit(Request {
            id: i,
            op: if i % 3 == 0 { ReqOp::Div } else { ReqOp::Mul },
            bits: [8, 16, 32][(i % 3) as usize],
            a: 40 + i,
            b: 3 + i,
        }));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.recv().unwrap();
        println!("  req {i}: {}", r.value);
    }
    let s = coord.shutdown();
    println!(
        "coordinator: {} reqs in {} words, lane utilization {:.0}%, energy {:.1} nJ",
        s.requests,
        s.words,
        s.lane_utilization() * 100.0,
        s.energy_pj / 1000.0
    );
}

/// Serving benchmark through the coordinator (windowed batch submission:
/// one response channel per 1024-request window, double-buffered so the
/// coordinator always has a window in flight).
fn serve(n: u64) {
    use simdive::coordinator::{BatchHandle, Coordinator, CoordinatorConfig, ReqOp, Request};
    use simdive::util::Rng;
    let coord = Coordinator::start(CoordinatorConfig::default());
    let mut rng = Rng::new(0xD15C0);
    let t0 = std::time::Instant::now();
    let mut done = 0u64;
    let mut submitted = 0u64;
    let mut pending: Option<BatchHandle> = None;
    while submitted < n {
        let window = (n - submitted).min(1024);
        let reqs: Vec<Request> = (submitted..submitted + window)
            .map(|i| {
                let bits = [8u32, 8, 8, 16, 16, 32][rng.below(6) as usize];
                Request {
                    id: i,
                    op: if rng.below(4) == 0 { ReqOp::Div } else { ReqOp::Mul },
                    bits,
                    a: rng.operand(bits),
                    b: rng.operand(bits),
                }
            })
            .collect();
        let handle = coord.submit_batch(reqs);
        if let Some(p) = pending.take() {
            done += p.wait().len() as u64;
        }
        pending = Some(handle);
        submitted += window;
    }
    if let Some(p) = pending.take() {
        done += p.wait().len() as u64;
    }
    let dt = t0.elapsed();
    let s = coord.shutdown();
    println!(
        "served {done} requests in {:.3}s ({:.1} kops/s) — {} words, lane util {:.0}%, \
         model energy {:.2} µJ",
        dt.as_secs_f64(),
        done as f64 / dt.as_secs_f64() / 1e3,
        s.words,
        s.lane_utilization() * 100.0,
        s.energy_pj / 1e6
    );
}
