//! The lock-free metrics registry: named [`Counter`]s, [`Gauge`]s and
//! log2 [`Hist`]ograms (DESIGN.md §12).
//!
//! Recording never takes a lock — every primitive is one (or a few)
//! relaxed atomic ops on an `Arc` handle the hot path holds directly.
//! The registry's own mutex guards only the name → slot map, touched at
//! registration and snapshot time.
//!
//! A name can hold *multiple instances* of the same primitive
//! ([`Registry::hist_instance`] / [`Registry::counter_instance`]): each
//! shard records into its own cache-line-private instance and the
//! snapshot merges them (bucket-wise / sum). `counter`/`gauge`/`hist`
//! are get-or-create on the first instance, so independent subsystems
//! naming the same metric share one handle.

use crate::coordinator::packer::ReqOp;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log2 histogram buckets: bucket `i` counts samples in
/// `[2^i, 2^{i+1})` nanoseconds, the top bucket absorbing ≥ 2^47 ns.
pub const HIST_BUCKETS: usize = 48;

/// Monotone event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Signed instantaneous level (queue depths, derived ppm estimates).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Gauge::default()
    }

    #[inline]
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Lock-free log2-bucketed histogram over nanosecond samples.
///
/// There is deliberately **no separate count field**: the sample count is
/// the bucket sum, and percentiles are derived from one consistent local
/// copy of the bucket array — so a reader racing concurrent writers can
/// never observe a rank larger than the buckets it scans (the snapshot
/// race the old `serve::stats::LatencyHist` had).
pub struct Hist {
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Hist {
    fn default() -> Self {
        Hist { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

/// Index of the bucket holding an `ns` sample.
#[inline]
pub fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        (63 - ns.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

impl Hist {
    pub fn new() -> Self {
        Hist::default()
    }

    /// Record one sample: one relaxed increment.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` samples of the same value in one increment — the
    /// amortized form the shard hot path uses for chunk/round-granular
    /// stage durations.
    #[inline]
    pub fn record_ns_n(&self, ns: u64, n: u64) {
        if n > 0 {
            self.buckets[bucket_of(ns)].fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// One consistent read of the buckets.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (out, b) in buckets.iter_mut().zip(self.buckets.iter()) {
            *out = b.load(Ordering::Relaxed);
        }
        HistSnapshot { buckets }
    }

    /// Percentile `p` in `(0, 1]` in microseconds, from one consistent
    /// bucket read (rank is derived from the *observed* bucket sum, never
    /// a separately-maintained count).
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.snapshot().percentile_us(p)
    }
}

/// An owned copy of a histogram's buckets: mergeable, encodable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; HIST_BUCKETS] }
    }
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Bucket-wise sum (per-shard instance merging).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    /// Percentile `p` in `(0, 1]`, reported as the upper bound of the
    /// holding bucket in microseconds (at most 2× off). Returns 0 when
    /// empty. The rank comes from this snapshot's own sum, so the scan
    /// can never walk past the last non-empty bucket.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                // Upper bound of bucket i is 2^{i+1} − 1 ns.
                return ((1u64 << (i + 1)) - 1) / 1000;
            }
        }
        unreachable!("rank {rank} exceeds observed bucket sum {n}")
    }
}

/// A metric's value in a [`Snapshot`] (and on the wire as `STATS2`).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Counter(u64),
    Gauge(i64),
    Hist(HistSnapshot),
}

/// A point-in-time copy of every registered metric, name-sorted.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub entries: Vec<(String, Value)>,
}

impl Snapshot {
    pub fn get(&self, name: &str) -> Option<&Value> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        match self.get(name)? {
            Value::Counter(v) => Some(*v),
            _ => None,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<i64> {
        match self.get(name)? {
            Value::Gauge(v) => Some(*v),
            _ => None,
        }
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        match self.get(name)? {
            Value::Hist(h) => Some(h),
            _ => None,
        }
    }

    pub fn push(&mut self, name: impl Into<String>, value: Value) {
        self.entries.push((name.into(), value));
    }
}

enum Slot {
    Counter(Vec<Arc<Counter>>),
    Gauge(Vec<Arc<Gauge>>),
    Hist(Vec<Arc<Hist>>),
}

/// The name → metric map. One per server (or per test); handles are
/// `Arc`s, so recording never touches the registry again.
#[derive(Default)]
pub struct Registry {
    slots: Mutex<BTreeMap<String, Slot>>,
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    /// Get-or-create the first counter instance under `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut slots = self.slots.lock().unwrap();
        match slots.entry(name.to_string()).or_insert_with(|| Slot::Counter(Vec::new())) {
            Slot::Counter(v) => {
                if v.is_empty() {
                    v.push(Arc::new(Counter::new()));
                }
                Arc::clone(&v[0])
            }
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Always append a fresh counter instance under `name` (merged on
    /// snapshot) — per-shard private counting.
    pub fn counter_instance(&self, name: &str) -> Arc<Counter> {
        let mut slots = self.slots.lock().unwrap();
        match slots.entry(name.to_string()).or_insert_with(|| Slot::Counter(Vec::new())) {
            Slot::Counter(v) => {
                let c = Arc::new(Counter::new());
                v.push(Arc::clone(&c));
                c
            }
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Get-or-create the gauge under `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut slots = self.slots.lock().unwrap();
        match slots.entry(name.to_string()).or_insert_with(|| Slot::Gauge(Vec::new())) {
            Slot::Gauge(v) => {
                if v.is_empty() {
                    v.push(Arc::new(Gauge::new()));
                }
                Arc::clone(&v[0])
            }
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Get-or-create the first histogram instance under `name`.
    pub fn hist(&self, name: &str) -> Arc<Hist> {
        let mut slots = self.slots.lock().unwrap();
        match slots.entry(name.to_string()).or_insert_with(|| Slot::Hist(Vec::new())) {
            Slot::Hist(v) => {
                if v.is_empty() {
                    v.push(Arc::new(Hist::new()));
                }
                Arc::clone(&v[0])
            }
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Always append a fresh histogram instance under `name` (bucket-wise
    /// merged on snapshot) — each shard records into its own instance.
    pub fn hist_instance(&self, name: &str) -> Arc<Hist> {
        let mut slots = self.slots.lock().unwrap();
        match slots.entry(name.to_string()).or_insert_with(|| Slot::Hist(Vec::new())) {
            Slot::Hist(v) => {
                let h = Arc::new(Hist::new());
                v.push(Arc::clone(&h));
                h
            }
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Snapshot every metric, merging same-name instances (counters and
    /// gauges sum; histograms sum bucket-wise). Entries come back sorted
    /// by name (the map is a `BTreeMap`).
    pub fn snapshot(&self) -> Snapshot {
        let slots = self.slots.lock().unwrap();
        let mut out = Snapshot::default();
        for (name, slot) in slots.iter() {
            let value = match slot {
                Slot::Counter(v) => Value::Counter(v.iter().map(|c| c.get()).sum()),
                Slot::Gauge(v) => Value::Gauge(v.iter().map(|g| g.get()).sum()),
                Slot::Hist(v) => {
                    let mut merged = HistSnapshot::default();
                    for h in v {
                        merged.merge(&h.snapshot());
                    }
                    Value::Hist(merged)
                }
            };
            out.push(name.clone(), value);
        }
        out
    }
}

/// The per-`{op, bits, w}` accuracy-tier counters (`tier.mul8.w4` …):
/// 2 ops × 3 widths × 9 knobs = 54 counters, indexed without hashing.
/// Registration is get-or-create, so the engine (which records) and the
/// serve snapshot (which reads them for delivered-MRED estimates) share
/// the same handles.
#[derive(Clone)]
pub struct Tiers {
    counters: Vec<Arc<Counter>>,
}

/// Supported operand widths, in tier-index order.
const TIER_WIDTHS: [u32; 3] = [8, 16, 32];
/// Accuracy knobs per `{op, width}` (`w` in `0..=8`).
const TIER_KNOBS: usize = 9;

impl Tiers {
    pub fn register(reg: &Registry) -> Tiers {
        let mut counters = Vec::with_capacity(2 * TIER_WIDTHS.len() * TIER_KNOBS);
        for op in ["mul", "div"] {
            for bits in TIER_WIDTHS {
                for w in 0..TIER_KNOBS {
                    counters.push(reg.counter(&format!("tier.{op}{bits}.w{w}")));
                }
            }
        }
        Tiers { counters }
    }

    fn index(op: ReqOp, bits: u32, w: u32) -> Option<usize> {
        let oi = match op {
            ReqOp::Mul => 0,
            ReqOp::Div => 1,
        };
        let bi = TIER_WIDTHS.iter().position(|&b| b == bits)?;
        let w = w as usize;
        if w >= TIER_KNOBS {
            return None;
        }
        Some((oi * TIER_WIDTHS.len() + bi) * TIER_KNOBS + w)
    }

    /// Count `n` completed requests on tier `{op, bits, w}`; out-of-range
    /// coordinates are ignored (they cannot come from validated traffic).
    #[inline]
    pub fn add(&self, op: ReqOp, bits: u32, w: u32, n: u64) {
        if let Some(i) = Tiers::index(op, bits, w) {
            self.counters[i].add(n);
        }
    }

    pub fn get(&self, op: ReqOp, bits: u32, w: u32) -> u64 {
        Tiers::index(op, bits, w).map(|i| self.counters[i].get()).unwrap_or(0)
    }

    /// Every `(op, bits, w, count)` with a non-zero count.
    pub fn nonzero(&self) -> Vec<(ReqOp, u32, u32, u64)> {
        let mut out = Vec::new();
        for (oi, op) in [ReqOp::Mul, ReqOp::Div].into_iter().enumerate() {
            for (bi, &bits) in TIER_WIDTHS.iter().enumerate() {
                for w in 0..TIER_KNOBS {
                    let n = self.counters[(oi * TIER_WIDTHS.len() + bi) * TIER_KNOBS + w].get();
                    if n > 0 {
                        out.push((op, bits, w as u32, n));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_record() {
        let reg = Registry::new();
        let c = reg.counter("a.count");
        c.inc();
        c.add(4);
        let g = reg.gauge("a.level");
        g.add(10);
        g.sub(3);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("a.count"), Some(5));
        assert_eq!(snap.gauge("a.level"), Some(7));
    }

    #[test]
    fn get_or_create_shares_one_handle() {
        let reg = Registry::new();
        let a = reg.counter("shared");
        let b = reg.counter("shared");
        a.inc();
        b.inc();
        assert_eq!(reg.snapshot().counter("shared"), Some(2));
    }

    #[test]
    fn instances_merge_on_snapshot() {
        let reg = Registry::new();
        let h0 = reg.hist_instance("stage.x");
        let h1 = reg.hist_instance("stage.x");
        h0.record_ns(1_000);
        h1.record_ns_n(1_000_000, 3);
        let c0 = reg.counter_instance("n");
        let c1 = reg.counter_instance("n");
        c0.add(2);
        c1.add(3);
        let snap = reg.snapshot();
        assert_eq!(snap.hist("stage.x").unwrap().count(), 4);
        assert_eq!(snap.counter("n"), Some(5));
    }

    #[test]
    fn snapshot_entries_are_name_sorted() {
        let reg = Registry::new();
        reg.counter("z.last");
        reg.counter("a.first");
        reg.gauge("m.middle");
        let snap = reg.snapshot();
        let names: Vec<&str> = snap.entries.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a.first", "m.middle", "z.last"]);
    }

    #[test]
    #[should_panic(expected = "another type")]
    fn type_conflicts_are_loud() {
        let reg = Registry::new();
        reg.counter("x");
        reg.gauge("x");
    }

    #[test]
    fn hist_percentiles_derive_rank_from_observed_sum() {
        let h = Hist::new();
        for _ in 0..99 {
            h.record_ns(1_000);
        }
        h.record_ns(1_000_000);
        let p50 = h.percentile_us(0.50);
        let p100 = h.percentile_us(1.0);
        assert!((1..=2).contains(&p50), "p50 = {p50}");
        assert!((1_000..=2_100).contains(&p100), "p100 = {p100}");
        assert_eq!(h.count(), 100);
    }

    #[test]
    fn empty_hist_is_zero() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_us(0.5), 0);
        assert_eq!(h.percentile_us(1.0), 0);
    }

    #[test]
    fn tiers_index_and_accumulate() {
        let reg = Registry::new();
        let t = Tiers::register(&reg);
        t.add(ReqOp::Mul, 8, 4, 10);
        t.add(ReqOp::Div, 32, 8, 2);
        t.add(ReqOp::Mul, 24, 0, 99); // unsupported width: ignored
        assert_eq!(t.get(ReqOp::Mul, 8, 4), 10);
        assert_eq!(t.get(ReqOp::Div, 32, 8), 2);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("tier.mul8.w4"), Some(10));
        assert_eq!(snap.counter("tier.div32.w8"), Some(2));
        assert_eq!(t.nonzero().len(), 2);
        // A second registration against the same registry shares handles.
        let t2 = Tiers::register(&reg);
        assert_eq!(t2.get(ReqOp::Mul, 8, 4), 10);
    }
}
