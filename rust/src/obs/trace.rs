//! Request lifecycle tracing: the [`Span`] every request carries through
//! the serving pipeline, and the seeded-sampled bounded [`TraceRing`] of
//! finished [`TraceEvent`]s behind `simdive trace` (DESIGN.md §12).
//!
//! A span is five timestamps against the process [`epoch`](super::epoch):
//!
//! ```text
//! t_admit ─ admission accepted, budget route resolved (serve)
//! t_submit ─ chunk handed to a shard channel (coordinator/engine)
//! t_fold ─ shard pulled the chunk and folded it into SIMD words
//! t_emit ─ the word holding this lane was released for execution
//! t_done ─ results unpacked, response routed back
//! ```
//!
//! plus `t_write` stamped by the connection writer when the response hits
//! the socket. Stage durations are the deltas:
//! `admit = submit−admit`, `queue = fold−submit`, `assemble = emit−fold`
//! (residue lanes wait extra rounds here), `execute = done−emit`,
//! `write = write−done`.
//!
//! Every request feeds the per-stage histograms; only a seeded 1-in-N
//! sample (decided at admission, deterministic for a fixed seed and
//! arrival index) is retained as a full event in the bounded ring, so
//! trace memory is O(capacity) regardless of load.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default bounded ring capacity (events, not requests).
pub const DEFAULT_TRACE_CAP: usize = 4096;

/// Default sampling rate: one traced request in this many admissions.
pub const DEFAULT_SAMPLE_RATE: u64 = 64;

/// SplitMix64 — the same seeded mixer `faults` uses, duplicated here so
/// `obs` stays dependency-free of the fault layer.
#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Per-request lifecycle timestamps, carried alongside the request from
/// admission to response routing. `Copy` and 5×8+4+1 bytes so threading
/// it through the shard channels costs a move, not an allocation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Span {
    /// Whether this request was selected for the trace ring. Stage
    /// histograms are recorded regardless.
    pub sampled: bool,
    /// Request shape: 0 = mul, 1 = div.
    pub op: u8,
    /// Operand width in bits (8/16/32).
    pub bits: u8,
    /// Accuracy knob `w`.
    pub w: u8,
    /// Executing shard index (stamped by the engine).
    pub shard: u8,
    pub t_admit_ns: u64,
    pub t_submit_ns: u64,
    pub t_fold_ns: u64,
    pub t_emit_ns: u64,
    pub t_done_ns: u64,
}

impl Span {
    /// A span stamped at admission time.
    pub fn admitted(sampled: bool, op: u8, bits: u8, w: u8) -> Span {
        Span { sampled, op, bits, w, shard: 0, t_admit_ns: super::now_ns(), ..Span::default() }
    }

    /// The inert span used when observability is disabled: never sampled,
    /// all timestamps zero, costs nothing to carry.
    pub fn disabled() -> Span {
        Span::default()
    }
}

/// A completed, sampled request: its span plus the socket-write stamp.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceEvent {
    pub id: u64,
    pub op: u8,
    pub bits: u8,
    pub w: u8,
    pub shard: u8,
    pub t_admit_ns: u64,
    pub t_submit_ns: u64,
    pub t_fold_ns: u64,
    pub t_emit_ns: u64,
    pub t_done_ns: u64,
    pub t_write_ns: u64,
}

/// Stage names, in pipeline order, matching `stage.*` histogram names.
pub const STAGE_NAMES: [&str; 5] = ["admit", "queue", "assemble", "execute", "write"];

impl TraceEvent {
    pub fn from_span(id: u64, span: &Span, t_write_ns: u64) -> TraceEvent {
        TraceEvent {
            id,
            op: span.op,
            bits: span.bits,
            w: span.w,
            shard: span.shard,
            t_admit_ns: span.t_admit_ns,
            t_submit_ns: span.t_submit_ns,
            t_fold_ns: span.t_fold_ns,
            t_emit_ns: span.t_emit_ns,
            t_done_ns: span.t_done_ns,
            t_write_ns,
        }
    }

    /// `(start_ns, duration_ns)` per stage, in [`STAGE_NAMES`] order.
    /// Durations saturate at zero so a racy or disabled stamp can never
    /// produce a wrap-around duration.
    pub fn stages(&self) -> [(u64, u64); 5] {
        let ts = [
            self.t_admit_ns,
            self.t_submit_ns,
            self.t_fold_ns,
            self.t_emit_ns,
            self.t_done_ns,
            self.t_write_ns,
        ];
        let mut out = [(0u64, 0u64); 5];
        for i in 0..5 {
            out[i] = (ts[i], ts[i + 1].saturating_sub(ts[i]));
        }
        out
    }

    pub fn op_name(&self) -> &'static str {
        if self.op == 0 {
            "mul"
        } else {
            "div"
        }
    }

    /// End-to-end latency (admission → socket write).
    pub fn total_ns(&self) -> u64 {
        self.t_write_ns.saturating_sub(self.t_admit_ns)
    }
}

/// Seeded-sampled bounded ring of trace events. `sample()` is lock-free;
/// `push`/`events` take a mutex, acceptable because only the sampled
/// 1-in-N requests ever reach it.
pub struct TraceRing {
    cap: usize,
    rate: u64,
    seed: u64,
    admissions: AtomicU64,
    ring: Mutex<VecDeque<TraceEvent>>,
}

impl TraceRing {
    pub fn new(cap: usize, rate: u64, seed: u64) -> Arc<TraceRing> {
        Arc::new(TraceRing {
            cap: cap.max(1),
            rate: rate.max(1),
            seed,
            admissions: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
        })
    }

    pub fn with_seed(seed: u64) -> Arc<TraceRing> {
        TraceRing::new(DEFAULT_TRACE_CAP, DEFAULT_SAMPLE_RATE, seed)
    }

    /// Decide (at admission) whether the next request is traced. The
    /// decision is a pure function of `(seed, arrival index)`, so a fixed
    /// seed yields a reproducible sample regardless of thread timing.
    #[inline]
    pub fn sample(&self) -> bool {
        let k = self.admissions.fetch_add(1, Ordering::Relaxed);
        self.rate == 1 || splitmix64(self.seed ^ k) % self.rate == 0
    }

    /// Retain a finished event, evicting the oldest past capacity.
    pub fn push(&self, ev: TraceEvent) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(ev);
    }

    /// Copy out the retained events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.ring.lock().unwrap().iter().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One JSON object per event, one event per line — grep/jq-friendly.
pub fn render_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        let [(_, admit), (_, queue), (_, assemble), (_, execute), (_, write)] = e.stages();
        out.push_str(&format!(
            "{{\"id\":{},\"op\":\"{}\",\"bits\":{},\"w\":{},\"shard\":{},\
             \"t_admit_ns\":{},\"admit_ns\":{},\"queue_ns\":{},\"assemble_ns\":{},\
             \"execute_ns\":{},\"write_ns\":{},\"total_ns\":{}}}\n",
            e.id,
            e.op_name(),
            e.bits,
            e.w,
            e.shard,
            e.t_admit_ns,
            admit,
            queue,
            assemble,
            execute,
            write,
            e.total_ns(),
        ));
    }
    out
}

/// Chrome trace format (`chrome://tracing`, Perfetto): one complete-phase
/// (`"X"`) slice per stage, `pid` = shard, `tid` = request id, µs units.
pub fn render_chrome(events: &[TraceEvent]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for e in events {
        for (name, (start, dur)) in STAGE_NAMES.iter().zip(e.stages()) {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"{}{}w{}\",\"ph\":\"X\",\
                 \"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":{}}}",
                name,
                e.op_name(),
                e.bits,
                e.w,
                start as f64 / 1e3,
                dur as f64 / 1e3,
                e.shard,
                e.id,
            ));
        }
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(id: u64) -> TraceEvent {
        TraceEvent {
            id,
            op: (id % 2) as u8,
            bits: 8,
            w: 4,
            shard: 1,
            t_admit_ns: 100,
            t_submit_ns: 150,
            t_fold_ns: 300,
            t_emit_ns: 900,
            t_done_ns: 1_000,
            t_write_ns: 1_500,
        }
    }

    #[test]
    fn stage_durations_partition_the_span() {
        let e = event(7);
        let stages = e.stages();
        let sum: u64 = stages.iter().map(|(_, d)| d).sum();
        assert_eq!(sum, e.total_ns());
        assert_eq!(stages[1], (150, 150), "queue = fold − submit");
        assert_eq!(stages[4], (1_000, 500), "write = write − done");
    }

    #[test]
    fn unstamped_spans_saturate_to_zero_durations() {
        let e = TraceEvent { id: 1, t_admit_ns: 500, ..TraceEvent::default() };
        for (_, d) in e.stages() {
            assert_eq!(d, 0);
        }
    }

    #[test]
    fn sampling_is_deterministic_and_near_rate() {
        let a = TraceRing::new(64, 16, 0xD15C0);
        let b = TraceRing::new(64, 16, 0xD15C0);
        let pa: Vec<bool> = (0..4_096).map(|_| a.sample()).collect();
        let pb: Vec<bool> = (0..4_096).map(|_| b.sample()).collect();
        assert_eq!(pa, pb, "same seed, same arrival order, same picks");
        let hits = pa.iter().filter(|&&s| s).count();
        assert!((128..=512).contains(&hits), "1-in-16 of 4096 ≈ 256, got {hits}");
        let c = TraceRing::new(64, 16, 0xBEEF);
        let pc: Vec<bool> = (0..4_096).map(|_| c.sample()).collect();
        assert_ne!(pa, pc, "a different seed picks a different sample");
    }

    #[test]
    fn ring_is_bounded_and_keeps_newest() {
        let ring = TraceRing::new(8, 1, 0);
        for id in 0..20 {
            ring.push(event(id));
        }
        let events = ring.events();
        assert_eq!(events.len(), 8);
        assert_eq!(events[0].id, 12);
        assert_eq!(events[7].id, 19);
    }

    #[test]
    fn rate_one_samples_everything() {
        let ring = TraceRing::new(4, 1, 99);
        assert!((0..100).all(|_| ring.sample()));
    }

    #[test]
    fn jsonl_is_one_balanced_object_per_line() {
        let out = render_jsonl(&[event(1), event(2)]);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            assert!(line.starts_with('{') && line.ends_with('}'));
            assert_eq!(
                line.matches('{').count(),
                line.matches('}').count(),
                "balanced braces in {line}"
            );
            assert!(line.contains("\"queue_ns\":150"));
        }
    }

    #[test]
    fn chrome_trace_is_balanced_and_has_five_slices_per_event() {
        let out = render_chrome(&[event(1)]);
        assert!(out.starts_with("{\"traceEvents\":["));
        assert!(out.ends_with("]}"));
        assert_eq!(out.matches("\"ph\":\"X\"").count(), 5);
        assert_eq!(out.matches('{').count(), out.matches('}').count());
        assert!(render_chrome(&[]).contains("[]"));
    }
}
