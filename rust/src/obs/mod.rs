//! Observability: a dependency-free metrics registry and request
//! lifecycle tracing (DESIGN.md §12).
//!
//! The serving stack needs to *see* what it delivers — live per-stage
//! latency, shard queue depth, and which `{op, bits, w}` accuracy tiers
//! traffic actually lands on — before any closed-loop accuracy control
//! (ROADMAP item 3) can exist. This module is that sensor layer:
//!
//! * [`registry`] — named counters, gauges and log2 histograms behind one
//!   [`Registry`]. Recording is a relaxed atomic op; the registry lock is
//!   taken only at registration and snapshot time. Per-shard histogram
//!   *instances* share one name and are merged (bucket-wise summed) on
//!   snapshot, so shard threads never contend on a shared cache line.
//! * [`trace`] — the request lifecycle [`Span`] (admission → submit →
//!   fold → emit → done → write timestamps against one process-wide
//!   monotonic epoch), per-stage duration recording, and a seeded-sampled
//!   bounded [`TraceRing`] of structured [`TraceEvent`]s exportable as
//!   JSONL or Chrome trace format (`simdive trace`).
//!
//! Metric naming: dot-separated lowercase paths, `<subsystem>.<what>`
//! (`serve.requests`, `stage.queue`, `shard.3.queue_depth`,
//! `tier.mul8.w4`, `route.budget_w2`, `delivered.mred_ppm`,
//! `faults.shard_panic`). Stage histograms record nanoseconds; the wire
//! and CLI surface microsecond percentiles.
//!
//! Everything here is `std`-only and engine-agnostic: the wire layer
//! encodes a [`Snapshot`] as the `STATS2` op, but `obs` itself knows
//! nothing about serving.

pub mod registry;
pub mod trace;

pub use registry::{Counter, Gauge, Hist, HistSnapshot, Registry, Snapshot, Tiers, Value};
pub use trace::{Span, TraceEvent, TraceRing};

use std::sync::OnceLock;
use std::time::Instant;

/// The process-wide monotonic epoch every span timestamp is measured
/// against. Fixed at first use, so timestamps from different threads are
/// directly comparable and fit in a `u64` of nanoseconds.
pub fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process [`epoch`]. Two calls from any threads
/// are ordered; the cost is one `Instant::now()`.
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotone_and_shared() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
        std::thread::spawn(|| {
            let c = now_ns();
            assert!(c > 0, "other threads share the same epoch");
        })
        .join()
        .unwrap();
    }
}
