"""L2 tests: quantized ANN forward + blend graph shapes and semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train


@pytest.fixture(scope="session")
def tiny_net(tables):
    weights, act_max, acc = train.train_mlp(hidden=(32,), train_n=1200, epochs=3)
    assert acc > 0.55, f"float training accuracy {acc}"
    wq_in = [(w, b, act_max[i], act_max[i + 1]) for i, (w, b) in enumerate(weights)]
    return model.quantize_net(wq_in), acc


def test_ann_forward_shapes(tiny_net):
    qlayers, _ = tiny_net
    x = jnp.zeros((4, train.IMG * train.IMG), dtype=jnp.uint8)
    logits, pred = model.ann_forward(x, qlayers)
    assert logits.shape == (4, train.CLASSES)
    assert pred.shape == (4,)


def test_ann_quantized_accuracy_tracks_float(tiny_net):
    qlayers, float_acc = tiny_net
    imgs, labels = train.make_dataset(200, seed=99)
    x = jnp.asarray(imgs.reshape(200, -1), dtype=jnp.uint8)
    _, pred = model.ann_forward(x, qlayers)
    acc = float((np.asarray(pred) == labels).mean())
    assert acc > float_acc - 0.15, f"quantized+simdive {acc} vs float {float_acc}"


def test_blend_matches_reference(tables):
    from compile.kernels import ref

    rng = np.random.default_rng(3)
    a = rng.integers(0, 256, (16, 16), dtype=np.uint8)
    b = rng.integers(0, 256, (16, 16), dtype=np.uint8)
    out = np.asarray(model.blend(jnp.asarray(a), jnp.asarray(b)))
    mul_f, _ = ref.table_f_units(8, tables)
    want = np.clip(
        np.asarray(ref.simdive_mul(a.astype(np.int64), b.astype(np.int64), 8, mul_f))
        >> 8,
        0,
        255,
    )
    np.testing.assert_array_equal(out, want)


def test_ann_graph_lowers_to_hlo(tiny_net):
    """The full L2 graph (with inlined Pallas kernels) lowers to HLO text."""
    qlayers, _ = tiny_net
    from compile.aot import to_hlo_text

    spec = jax.ShapeDtypeStruct((4, train.IMG * train.IMG), jnp.uint8)
    lowered = jax.jit(lambda x: model.ann_forward(x, qlayers)).lower(spec)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 1000
