"""L1 correctness: Pallas kernels vs the jnp oracle (hypothesis sweeps
shapes/values) and both vs the Rust golden vectors."""

import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref, simdive


def golden_dir():
    return os.path.join(ref.artifacts_root(), "golden")


def _golden_cases(name):
    path = os.path.join(golden_dir(), name)
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    rows = np.loadtxt(path, dtype=np.uint64).reshape(-1, 3)
    return rows[:, 0], rows[:, 1], rows[:, 2]


@pytest.mark.parametrize("bits", [8, 16])
def test_mul_matches_rust_golden(tables, bits):
    a, b, want = _golden_cases(f"mul_{bits}_w8.txt")
    a, b, want = a.astype(np.int64), b.astype(np.int64), want.astype(np.int64)
    mul_f, _ = ref.table_f_units(bits, tables)
    got = np.asarray(ref.simdive_mul(a, b, bits, mul_f))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("bits", [8, 16])
def test_div_matches_rust_golden(tables, bits):
    a, b, want = _golden_cases(f"div_{bits}_w8.txt")
    a, b, want = a.astype(np.int64), b.astype(np.int64), want.astype(np.int64)
    _, div_f = ref.table_f_units(bits, tables)
    got = np.asarray(ref.simdive_div(a, b, bits, div_f))
    np.testing.assert_array_equal(got, want)


def test_mul_32bit_golden_subset(tables):
    # 32-bit cases, restricted to the int64-safe range (the jnp oracle
    # works in int64; the Rust model covers the full u64 range).
    a, b, want = _golden_cases("mul_32_w8.txt")
    keep = (a.astype(object) * b.astype(object)) < 2**61
    a = a[keep].astype(np.int64)
    b = b[keep].astype(np.int64)
    want = want[keep].astype(np.int64)
    mul_f, _ = ref.table_f_units(32, tables)
    got = np.asarray(ref.simdive_mul(a, b, 32, mul_f))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 64),
    st.integers(0, 2**32 - 1),
)
def test_pallas_kernel_matches_ref_random_shapes(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, n, dtype=np.int64)
    b = rng.integers(0, 256, n, dtype=np.int64)
    mul_f, div_f = ref.table_f_units(8)
    kp = np.asarray(simdive.simdive_mul(jnp.asarray(a), jnp.asarray(b), bits=8))
    rp = np.asarray(ref.simdive_mul(a, b, 8, mul_f))
    np.testing.assert_array_equal(kp, rp)
    kq = np.asarray(simdive.simdive_div(jnp.asarray(a), jnp.asarray(b), bits=8))
    rq = np.asarray(ref.simdive_div(a, b, 8, div_f))
    np.testing.assert_array_equal(kq, rq)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pallas_kernel_16bit(seed):
    rng = np.random.default_rng(seed)
    shape = (4, 17)
    a = rng.integers(0, 65536, shape, dtype=np.int64)
    b = rng.integers(0, 65536, shape, dtype=np.int64)
    mul_f, _ = ref.table_f_units(16)
    kp = np.asarray(simdive.simdive_mul(jnp.asarray(a), jnp.asarray(b), bits=16))
    rp = np.asarray(ref.simdive_mul(a, b, 16, mul_f))
    np.testing.assert_array_equal(kp, rp)


def test_paper_running_example(tables):
    mul_f, div_f = ref.table_f_units(8, tables)
    # 43 × 10: Mitchell gives 408, accurate 430; SIMDive must be closer.
    p = int(ref.simdive_mul(np.array([43]), np.array([10]), 8, mul_f)[0])
    assert abs(430 - p) < abs(430 - 408)
    q = int(ref.simdive_div(np.array([43]), np.array([10]), 8, div_f)[0])
    assert q == 4


def test_zero_conventions(tables):
    mul_f, div_f = ref.table_f_units(8, tables)
    assert int(ref.simdive_mul(np.array([0]), np.array([9]), 8, mul_f)[0]) == 0
    assert int(ref.simdive_div(np.array([9]), np.array([0]), 8, div_f)[0]) == 255
    assert int(ref.simdive_div(np.array([0]), np.array([9]), 8, div_f)[0]) == 0


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_gemm_kernel_matches_scalar_products(seed):
    rng = np.random.default_rng(seed)
    m, k, n = 5, 23, 9
    x = rng.integers(0, 256, (m, k), dtype=np.int64)
    wq = rng.integers(-127, 128, (k, n), dtype=np.int64)
    got = np.asarray(
        simdive.simdive_matmul_q8(
            jnp.asarray(x), jnp.asarray(np.abs(wq)), jnp.asarray(np.sign(wq))
        )
    )
    mul_f, _ = ref.table_f_units(8)
    want = np.zeros((m, n), dtype=np.int64)
    for i in range(m):
        prods = np.asarray(ref.simdive_mul(x[i][:, None], np.abs(wq), 8, mul_f))
        want[i] = (prods * np.sign(wq)).sum(axis=0)
    np.testing.assert_array_equal(got, want)


def test_error_statistics_match_paper_regime(tables):
    """Mean relative error of the 8-bit kernel ≈ the paper's <1.2%."""
    mul_f, _ = ref.table_f_units(8, tables)
    a, b = np.meshgrid(np.arange(1, 256), np.arange(1, 256))
    a, b = a.ravel(), b.ravel()
    approx = np.asarray(ref.simdive_mul(a, b, 8, mul_f)).astype(float)
    exact = (a * b).astype(float)
    are = float(np.mean(np.abs(exact - approx) / exact)) * 100
    assert are < 1.2, f"ARE {are:.3f}%"
