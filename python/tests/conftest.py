import os
import sys

import jax
import pytest

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.normpath(os.path.join(os.path.dirname(__file__), "..")))


def golden_dir() -> str:
    root = os.environ.get(
        "SIMDIVE_ARTIFACTS",
        os.path.normpath(
            os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        ),
    )
    return os.path.join(root, "golden")


@pytest.fixture(scope="session")
def tables():
    """The w=8 correction tables exported by the Rust side."""
    from compile.kernels import ref

    path = os.path.join(golden_dir(), "tables_w8.txt")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first (repro export-golden)")
    return ref.load_tables(path)
