"""Pure-jnp oracle for the SIMDive arithmetic (build-time only).

Independent transcription of DESIGN.md §4's bit-exact contract, used by
pytest to validate the Pallas kernels, and itself pinned to the Rust
behavioral models through the golden vectors exported by
``repro export-golden``.

All integer math runs in int64 (``jax_enable_x64`` is switched on by
conftest / aot).
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

TABLE_RESOLUTION_BITS = 12


def artifacts_root() -> str:
    return os.environ.get(
        "SIMDIVE_ARTIFACTS",
        os.path.normpath(
            os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts")
        ),
    )


def load_tables(path: str | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Load the w=8 correction tables exported by ``repro export-golden``.

    Returns (mul, div) int32 arrays of shape (8, 8) in 2^-12 fixed point.
    """
    if path is None:
        path = os.path.join(artifacts_root(), "golden", "tables_w8.txt")
    mul = np.zeros((8, 8), dtype=np.int32)
    div = np.zeros((8, 8), dtype=np.int32)
    with open(path) as f:
        for line in f:
            if line.startswith("#") or not line.strip():
                continue
            op, i, j, v = line.split()
            (mul if op == "mul" else div)[int(i), int(j)] = int(v)
    return mul, div


def _scale_to_f(c12: np.ndarray, bits: int) -> np.ndarray:
    """Coefficient into F-bit units, truncating the magnitude (§4)."""
    f = bits - 1
    mag = np.abs(c12.astype(np.int64))
    if f >= TABLE_RESOLUTION_BITS:
        scaled = mag << (f - TABLE_RESOLUTION_BITS)
    else:
        scaled = mag >> (TABLE_RESOLUTION_BITS - f)
    return np.where(c12 < 0, -scaled, scaled)


def table_f_units(bits: int, tables=None) -> tuple[np.ndarray, np.ndarray]:
    """(mul, div) tables pre-scaled to F-bit units for a given width."""
    mul, div = tables if tables is not None else load_tables()
    return _scale_to_f(mul, bits), _scale_to_f(div, bits)


def _lod(x):
    """Position of the leading one (x ≥ 1), via binary search."""
    k = jnp.zeros_like(x, dtype=jnp.int64)
    v = x.astype(jnp.int64)
    for shift in (32, 16, 8, 4, 2, 1):
        hit = v >= (jnp.int64(1) << shift)
        k = jnp.where(hit, k + shift, k)
        v = jnp.where(hit, v >> shift, v)
    return k


def _frac(x, k, bits: int):
    f = bits - 1
    return ((x.astype(jnp.int64) - (jnp.int64(1) << k)) << (f - k)).astype(jnp.int64)


def _region(frac, bits: int):
    return (frac >> (bits - 1 - 3)) & 0x7


def _table_select(table_f, ri, rj):
    """Correction lookup without `gather`: a select-sum over the 64 region
    constants. Gather from jax ≥ 0.8's StableHLO mis-executes on the
    xla_extension 0.5.1 runtime the Rust side embeds (silently wrong
    results), so the AOT-shipped graphs — and, for bit-identity, the
    oracle too — avoid it. The 64 constants fold into the kernel like the
    paper's 64-entry LUT bank."""
    t = np.asarray(table_f).reshape(8, 8)
    idx = ri * 8 + rj
    c = jnp.zeros_like(idx, dtype=jnp.int64)
    for k in range(64):
        c = c + jnp.where(idx == k, jnp.int64(int(t[k // 8, k % 8])), jnp.int64(0))
    return c


def simdive_mul(x, y, bits: int, mul_table_f) -> jnp.ndarray:
    """SIMDive multiply, elementwise over integer arrays (w=8 tables)."""
    f = bits - 1
    x = jnp.asarray(x).astype(jnp.int64)
    y = jnp.asarray(y).astype(jnp.int64)
    safe_x = jnp.maximum(x, 1)
    safe_y = jnp.maximum(y, 1)
    k1 = _lod(safe_x)
    k2 = _lod(safe_y)
    f1 = _frac(safe_x, k1, bits)
    f2 = _frac(safe_y, k2, bits)
    c = _table_select(mul_table_f, _region(f1, bits), _region(f2, bits))
    t = f1 + f2 + c
    ovf = t >= (jnp.int64(1) << f)
    mant = jnp.where(ovf, t, t + (jnp.int64(1) << f))
    e = k1 + k2 + ovf.astype(jnp.int64)
    p = jnp.where(
        e >= f,
        mant << jnp.clip(e - f, 0, 62),
        mant >> jnp.clip(f - e, 0, 62),
    )
    if bits < 31:
        p = jnp.minimum(p, (jnp.int64(1) << (2 * bits)) - 1)
    return jnp.where((x == 0) | (y == 0), 0, p)


def simdive_div(x, y, bits: int, div_table_f) -> jnp.ndarray:
    """SIMDive divide, elementwise (w=8 tables)."""
    f = bits - 1
    x = jnp.asarray(x).astype(jnp.int64)
    y = jnp.asarray(y).astype(jnp.int64)
    safe_x = jnp.maximum(x, 1)
    safe_y = jnp.maximum(y, 1)
    k1 = _lod(safe_x)
    k2 = _lod(safe_y)
    f1 = _frac(safe_x, k1, bits)
    f2 = _frac(safe_y, k2, bits)
    c = _table_select(div_table_f, _region(f1, bits), _region(f2, bits))
    t = f1 - f2 + c
    neg = t < 0
    mant = jnp.where(neg, (jnp.int64(2) << f) + t, (jnp.int64(1) << f) + t)
    mant = jnp.maximum(mant, 0)
    e = k1 - k2 - neg.astype(jnp.int64)
    s = f - e
    q = jnp.where(
        s <= 0,
        mant << jnp.clip(-s, 0, 62),
        jnp.where(s >= 62, 0, mant >> jnp.clip(s, 0, 62)),
    )
    maxv = (jnp.int64(1) << bits) - 1
    q = jnp.minimum(q, maxv)
    q = jnp.where(x == 0, 0, q)
    return jnp.where(y == 0, maxv, q)


def exact_mul(x, y):
    return jnp.asarray(x).astype(jnp.int64) * jnp.asarray(y).astype(jnp.int64)
