"""Layer-1 Pallas kernels: SIMDive approximate multiply / divide and the
approximate-multiply GEMM used by the quantized ANN (paper §4.3).

Always lowered with ``interpret=True`` — the CPU PJRT client cannot run
Mosaic custom-calls (see /opt/xla-example/README.md). Hardware adaptation
(DESIGN.md §2): the paper's LUT/carry-chain bit-twiddling becomes VPU-style
vectorized integer lanes; the 64 correction coefficients fold into the
kernel as constants (a select-sum — the analogue of the 8×LUT6 bank, and
gather-free because the embedded xla_extension 0.5.1 mis-executes jax 0.8
StableHLO gathers); the GEMM tiles activations×weights into VMEM blocks via
BlockSpec with the SIMDive product applied elementwise inside the tile
before an exact reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Tile sizes for the GEMM kernel (VMEM-sized blocks; see DESIGN.md §7).
TILE_M = 8
TILE_N = 64


def _mul_kernel(bits, table, x_ref, y_ref, o_ref):
    # `table` is a host-side numpy constant; ref._table_select folds it
    # into the kernel as 64 scalar constants at trace time.
    o_ref[...] = ref.simdive_mul(x_ref[...], y_ref[...], bits, table)


def _div_kernel(bits, table, x_ref, y_ref, o_ref):
    o_ref[...] = ref.simdive_div(x_ref[...], y_ref[...], bits, table)


@functools.partial(jax.jit, static_argnames=("bits",))
def simdive_mul(x, y, bits: int = 8):
    """Elementwise SIMDive multiply via a Pallas kernel."""
    mul_f, _ = ref.table_f_units(bits)
    kern = functools.partial(_mul_kernel, bits, tuple(map(int, mul_f.ravel())))
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int64),
        interpret=True,
    )(x, y)


@functools.partial(jax.jit, static_argnames=("bits",))
def simdive_div(x, y, bits: int = 8):
    """Elementwise SIMDive divide via a Pallas kernel."""
    _, div_f = ref.table_f_units(bits)
    kern = functools.partial(_div_kernel, bits, tuple(map(int, div_f.ravel())))
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int64),
        interpret=True,
    )(x, y)


def _gemm_kernel(table, x_ref, wmag_ref, wsgn_ref, o_ref):
    """Full approximate GEMM in one kernel invocation.

    x: (M, K) activations; wmag: (K, N) |w|; wsgn: (K, N) ±1. Product per
    element through SIMDive-8, exact accumulation (the paper's ANN
    experiment: only multipliers are approximate). K is consumed in
    trace-time chunks to bound the broadcast working set (the VMEM tile) —
    and the kernel is deliberately grid-free: jax 0.8's grid lowering
    (while + dynamic-update-slice) mis-executes on the embedded
    xla_extension 0.5.1 runtime, like StableHLO gather (see module docs).
    """
    x = x_ref[...].astype(jnp.int64)  # (M, K)
    wm = wmag_ref[...].astype(jnp.int64)  # (K, N)
    ws = wsgn_ref[...].astype(jnp.int64)
    k = x.shape[1]
    acc = jnp.zeros((x.shape[0], wm.shape[1]), dtype=jnp.int64)
    chunk = 128
    for k0 in range(0, k, chunk):
        k1 = min(k0 + chunk, k)
        p = ref.simdive_mul(x[:, k0:k1, None], wm[None, k0:k1, :], 8, table)
        acc = acc + jnp.sum(p * ws[None, k0:k1, :], axis=1)
    o_ref[...] = acc


@jax.jit
def simdive_matmul_q8(x_u8, w_mag_u8, w_sgn):
    """Quantized approximate GEMM: `(M,K) × (K,N) → (M,N) i64`.

    Every scalar product routes through the SIMDive-8 multiplier, signs are
    re-applied and accumulation is exact — bit-compatible with the Rust
    `QuantMlp` inference path.
    """
    m, k = x_u8.shape
    k2, n = w_mag_u8.shape
    assert k == k2, (x_u8.shape, w_mag_u8.shape)
    mul_f, _ = ref.table_f_units(8)
    kern = functools.partial(_gemm_kernel, tuple(map(int, mul_f.ravel())))
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int64),
        interpret=True,
    )(x_u8, w_mag_u8, w_sgn)
