"""Pallas kernels (L1) and their jnp oracle."""
