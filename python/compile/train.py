"""Train the paper's MLP on the synthetic digits dataset (numpy SGD,
build-time only) and return float weights + calibration ranges for
quantization. Same dataset *spec* as `rust/src/datasets` (seven-segment
glyphs + augmentation); implementations are independent, which is fine —
Table 4 compares accuracies *between arithmetic variants*, not between
frameworks."""

from __future__ import annotations

import numpy as np

IMG = 28
CLASSES = 10

SEGMENTS = [
    [1, 1, 1, 1, 1, 1, 0],
    [0, 1, 1, 0, 0, 0, 0],
    [1, 1, 0, 1, 1, 0, 1],
    [1, 1, 1, 1, 0, 0, 1],
    [0, 1, 1, 0, 0, 1, 1],
    [1, 0, 1, 1, 0, 1, 1],
    [1, 0, 1, 1, 1, 1, 1],
    [1, 1, 1, 0, 0, 0, 0],
    [1, 1, 1, 1, 1, 1, 1],
    [1, 1, 1, 1, 0, 1, 1],
]
SEG_LINES = [
    (True, 0.15, 0.28, 0.72),
    (False, 0.72, 0.15, 0.5),
    (False, 0.72, 0.5, 0.85),
    (True, 0.85, 0.28, 0.72),
    (False, 0.28, 0.5, 0.85),
    (False, 0.28, 0.15, 0.5),
    (True, 0.5, 0.28, 0.72),
]


def render_digit(label: int, rng: np.random.Generator) -> np.ndarray:
    thick = 0.06 + rng.random() * 0.03
    sx, sy = 0.8 + rng.random() * 0.4, 0.8 + rng.random() * 0.4
    shear = (rng.random() - 0.5) * 0.3
    dx, dy = (rng.random() - 0.5) * 0.18, (rng.random() - 0.5) * 0.18
    ys, xs = np.mgrid[0:IMG, 0:IMG]
    u0 = (xs + 0.5) / IMG
    v0 = (ys + 0.5) / IMG
    v = (v0 - 0.5 - dy) / sy + 0.5
    u = (u0 - 0.5 - dx) / sx + 0.5 - shear * (v0 - 0.5)
    img = np.zeros((IMG, IMG))
    for si, (horiz, line, lo, hi) in enumerate(SEG_LINES):
        if not SEGMENTS[label][si]:
            continue
        if horiz:
            d_line = np.abs(v - line)
            d_span = np.maximum(lo - u, u - hi).clip(min=0)
        else:
            d_line = np.abs(u - line)
            d_span = np.maximum(lo - v, v - hi).clip(min=0)
        d = np.maximum(d_line, d_span)
        img = np.maximum(img, (1 - (d / thick) ** 2).clip(min=0) * (d < thick))
    img = img * (200 + rng.random() * 55) + rng.normal(0, 40, img.shape)
    return img.clip(0, 255).astype(np.uint8)


def make_dataset(count: int, seed: int):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, CLASSES, count)
    imgs = np.stack([render_digit(int(l), rng) for l in labels])
    return imgs, labels


def train_mlp(hidden=(100,), train_n=6000, epochs=5, lr0=0.1, seed=7):
    """Train; returns (weights list [(w, b)], act_max per layer, test acc)."""
    x, y = make_dataset(train_n, seed)
    xt, yt = make_dataset(1000, seed + 1)
    xf = x.reshape(train_n, -1) / 255.0
    xtf = xt.reshape(len(xt), -1) / 255.0

    dims = [IMG * IMG, *hidden, CLASSES]
    rng = np.random.default_rng(seed + 2)
    ws = [
        rng.normal(0, np.sqrt(2.0 / dims[i]), (dims[i], dims[i + 1])).astype(np.float32)
        for i in range(len(dims) - 1)
    ]
    bs = [np.zeros(d, dtype=np.float32) for d in dims[1:]]

    def forward(xb):
        acts = [xb]
        for i, (w, b) in enumerate(zip(ws, bs)):
            z = acts[-1] @ w + b
            acts.append(np.maximum(z, 0) if i + 1 < len(ws) else z)
        return acts

    n = len(xf)
    for epoch in range(epochs):
        lr = lr0 / (1 + 0.5 * epoch)
        order = rng.permutation(n)
        for start in range(0, n, 32):
            idx = order[start : start + 32]
            xb, yb = xf[idx], y[idx]
            acts = forward(xb)
            logits = acts[-1]
            p = np.exp(logits - logits.max(axis=1, keepdims=True))
            p /= p.sum(axis=1, keepdims=True)
            delta = p
            delta[np.arange(len(idx)), yb] -= 1
            delta /= len(idx)
            for li in reversed(range(len(ws))):
                grad_w = acts[li].T @ delta
                grad_b = delta.sum(axis=0)
                if li > 0:
                    delta = (delta @ ws[li].T) * (acts[li] > 0)
                ws[li] -= lr * grad_w
                bs[li] -= lr * grad_b

    acts_t = forward(xtf)
    acc = float((acts_t[-1].argmax(axis=1) == yt).mean())
    # Calibration: per-layer activation maxima over a training slice.
    acts_c = forward(xf[:500])
    act_max = [1.0] + [float(a.max()) for a in acts_c[1:]]
    return list(zip(ws, bs)), act_max, acc


if __name__ == "__main__":
    _, _, acc = train_mlp(train_n=2000, epochs=3)
    print(f"float test accuracy: {acc:.3f}")
