"""AOT pipeline: train → quantize → lower the L2 graphs (with the L1
Pallas kernels inlined, interpret mode) to **HLO text** artifacts the Rust
runtime loads via the PJRT C API.

HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model, train  # noqa: E402

ANN_BATCH = 32
BLEND_SIZE = 256


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-n", type=int, default=4000)
    ap.add_argument("--epochs", type=int, default=4)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # ---- train + quantize the ANN (build-time Python) ----
    weights, act_max, float_acc = train.train_mlp(
        hidden=(100,), train_n=args.train_n, epochs=args.epochs
    )
    print(f"trained MLP: float accuracy {float_acc:.3f}")
    wq_in = [
        (w, b, act_max[i], act_max[i + 1]) for i, (w, b) in enumerate(weights)
    ]
    qlayers = model.quantize_net(wq_in)

    # ---- lower ann_forward (quantized weights baked as constants; the
    # runtime feeds i32 pixels — the xla crate exposes no u8 literals) ----
    def ann(x_i32):
        return model.ann_forward(x_i32, qlayers)

    spec = jax.ShapeDtypeStruct((ANN_BATCH, train.IMG * train.IMG), jnp.int32)
    lowered = jax.jit(ann).lower(spec)
    path = os.path.join(args.out, "ann_fwd.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # ---- lower the Fig.-3 blend graph ----
    img_spec = jax.ShapeDtypeStruct((BLEND_SIZE, BLEND_SIZE), jnp.int32)
    lowered = jax.jit(model.blend).lower(img_spec, img_spec)
    path = os.path.join(args.out, "blend.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # ---- float weights bundle for the Rust runtime / examples ----
    manifest_lines = []
    blobs = []
    for i, (w, b) in enumerate(weights):
        manifest_lines.append(f"w{i} {w.shape[0]} {w.shape[1]}")
        blobs.append(np.asarray(w, dtype=np.float32).ravel())
        manifest_lines.append(f"b{i} {b.shape[0]}")
        blobs.append(np.asarray(b, dtype=np.float32).ravel())
    with open(os.path.join(args.out, "weights.manifest"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    np.concatenate(blobs).tofile(os.path.join(args.out, "weights.bin"))
    print(f"wrote weights bundle ({len(weights)} layers)")

    # ---- a small labelled eval batch for the serving example ----
    imgs, labels = train.make_dataset(ANN_BATCH, seed=4242)
    imgs.astype(np.uint8).tofile(os.path.join(args.out, "eval_batch.u8"))
    labels.astype(np.uint8).tofile(os.path.join(args.out, "eval_labels.u8"))
    print("wrote eval batch")


if __name__ == "__main__":
    main()
