"""Layer-2 JAX model graphs (build-time only; AOT-lowered by aot.py).

* ``ann_forward`` — the paper's §4.3 quantized MLP inference with every
  weight×activation product routed through the SIMDive-8 Pallas GEMM
  kernel; mirrors the Rust `ann::QuantMlp` semantics so the PJRT-served
  model and the Rust Table-4 evaluation agree.
* ``blend`` — the Fig.-3 multiply-blend (elementwise SIMDive-8 products).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import simdive as kernels


def quantize_net(weights: list[tuple]) -> list[dict]:
    """Post-training 8-bit quantization, mirroring Rust `QuantMlp`.

    `weights` is [(w, b, act_max_in, act_max_out), …] with float arrays
    (w is [in, out]). Returns per-layer dicts of arrays ready to be baked
    into the ann graph.
    """
    import numpy as np

    layers = []
    for w, b, amax_in, amax_out in weights:
        wmax = max(float(np.abs(w).max()), 1e-6)
        sw = 127.0 / wmax
        sa = 255.0 / max(amax_in, 1e-6)
        sa_next = 255.0 / max(amax_out, 1e-6)
        wq = np.clip(np.round(w * sw), -127, 127).astype(np.int64)
        layers.append(
            dict(
                w_mag=np.abs(wq),
                w_sgn=np.sign(wq).astype(np.int64),
                b_q=(b * sw * sa).astype(np.int64),
                requant=np.float32(sa_next / (sw * sa)),
            )
        )
    return layers


def ann_forward(x_u8, qlayers: list[dict]):
    """Quantized MLP forward: u8 pixels → logits (i64) + predicted class.

    Every product goes through the SIMDive Pallas GEMM; accumulation,
    bias-add and requantization are exact — the paper's "replace the
    multipliers only" experiment.
    """
    act = x_u8.astype(jnp.int64)
    n_layers = len(qlayers)
    for li, layer in enumerate(qlayers):
        acc = kernels.simdive_matmul_q8(act, layer["w_mag"], layer["w_sgn"])
        acc = acc + layer["b_q"][None, :]
        if li + 1 < n_layers:
            v = jnp.maximum(acc, 0).astype(jnp.float32) * layer["requant"]
            act = jnp.clip(jnp.round(v), 0, 255).astype(jnp.int64)
        else:
            return acc, jnp.argmax(acc, axis=-1)
    raise AssertionError("empty network")


def blend(a_img, b_img):
    """Fig.-3 multiply-blend: `out = SIMDive8(a, b) >> 8` (8-bit range,
    carried as i32 for the PJRT interface)."""
    p = kernels.simdive_mul(a_img.astype(jnp.int64), b_img.astype(jnp.int64), bits=8)
    return jnp.clip(p >> 8, 0, 255).astype(jnp.int32)
