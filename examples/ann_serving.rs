//! End-to-end serving driver (DESIGN.md §5 "E2E driver"): loads the
//! AOT-compiled quantized-ANN artifact (JAX/Pallas → HLO text), serves
//! batched classification requests on the PJRT CPU client from Rust, and
//! reports accuracy, latency and throughput. Python is not on this path.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example ann_serving [-- <batches>]`

use std::time::Instant;

fn bytes_of(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn main() -> anyhow::Result<()> {
    let batches: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50);
    let dir = simdive::runtime::default_artifacts_dir();
    let eng = simdive::runtime::Engine::load(&dir)?;
    println!(
        "engine up: platform={} models={:?} weights={:?}",
        eng.platform(),
        eng.models(),
        eng.weight_manifest().iter().map(|(n, d)| format!("{n}{d:?}")).collect::<Vec<_>>()
    );

    // Bundled labelled eval batch (32 images) — accuracy check.
    let imgs = std::fs::read(dir.join("eval_batch.u8"))?;
    let labels = std::fs::read(dir.join("eval_labels.u8"))?;
    let vals: Vec<i32> = imgs.iter().map(|&v| v as i32).collect();
    let lit = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[32, 784],
        bytes_of(&vals),
    )?;
    let out = eng.run("ann_fwd", std::slice::from_ref(&lit))?;
    let preds = out[1].to_vec::<i64>()?;
    let correct = preds.iter().zip(&labels).filter(|(&p, &l)| p == l as i64).count();
    println!("accuracy on bundled eval batch: {correct}/{} (SIMDive-8 multipliers)", labels.len());

    // Serving loop: batched requests, latency/throughput stats.
    let mut lat_ms: Vec<f64> = Vec::with_capacity(batches);
    let t0 = Instant::now();
    for _ in 0..batches {
        let t = Instant::now();
        let out = eng.run("ann_fwd", std::slice::from_ref(&lit))?;
        std::hint::black_box(&out);
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let total = t0.elapsed().as_secs_f64();
    let mean = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;
    let p99 = simdive::util::stats::percentile(&mut lat_ms, 0.99);
    println!(
        "served {batches} batches of 32: mean latency {mean:.2} ms, p99 {p99:.2} ms, \
         throughput {:.0} images/s",
        batches as f64 * 32.0 / total
    );
    Ok(())
}
